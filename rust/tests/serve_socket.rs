//! Loopback integration tests for the network serving front-end: every
//! socket-served output must equal the direct `SparseModel::forward`
//! result bit-for-bit, backpressure must answer with a well-formed retry
//! response, the adaptive batcher must be visible in the stats, and a
//! slow client must stall only its own connection (egress-queue
//! isolation).
//!
//! All tests bind 127.0.0.1 port 0 (kernel-assigned), so they are safe to
//! run in parallel; CI still serializes them (`--test-threads=1`) out of
//! caution. Test names share the `socket_` prefix so the main test sweep
//! can `--skip socket_`.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;

use srigl::inference::{frontend, Activation, EngineBuilder, LayerSpec, Repr, SparseModel};
use srigl::net::{
    read_response, write_request, Client, Reply, RequestFrame, ResponseBody, MAX_FRAME_BYTES,
};
use srigl::util::rng::Rng;

const D_IN: usize = 64;
const D_OUT: usize = 16;

fn test_model(repr: Repr) -> Arc<SparseModel> {
    let spec = |n, act| LayerSpec {
        n,
        repr,
        sparsity: 0.9,
        ablated_frac: 0.25,
        activation: act,
    };
    Arc::new(
        SparseModel::synth(
            D_IN,
            &[
                spec(48, Activation::Relu),
                spec(32, Activation::Relu),
                spec(D_OUT, Activation::Identity),
            ],
            17,
        )
        .unwrap(),
    )
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: idx {i}: {g} vs {w} (must be bit-for-bit)");
    }
}

/// ≥2 concurrent client threads, mixed row counts: every response equals
/// the direct forward bit-for-bit.
#[test]
fn socket_outputs_match_direct_forward_across_clients() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(2)
            .adaptive(8)
            .queue_capacity(256)
            .cache_capacity(64)
            .retry_after_ms(1),
    )
    .unwrap();
    let addr = handle.addr();

    std::thread::scope(|s| {
        for t in 0..3u64 {
            let model = Arc::clone(&model);
            s.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut rng = Rng::new(0x50C + t);
                for req in 0..30usize {
                    let rows = 1 + (req % 3);
                    let x: Vec<f32> = (0..rows * D_IN).map(|_| rng.normal_f32()).collect();
                    let got = client.infer_retrying(rows, &x, 50).expect("infer");
                    let want = model.forward_vec(&x, rows, 1);
                    assert_bits_eq(&got, &want, &format!("client {t} req {req} rows {rows}"));
                }
            });
        }
    });

    let stats = handle.stop();
    assert_eq!(stats.connections_total, 3);
    assert_eq!(stats.connections_active, 0, "all readers exited before the stats were read");
    assert_eq!(
        stats.served + stats.cache_hits,
        3 * 30,
        "every request answered exactly once (rejected={})",
        stats.rejected
    );
    assert_eq!(stats.bad_requests, 0);
    assert_eq!(stats.dropped_responses, 0, "prompt readers never overflow their egress");
}

/// Sending the same payload twice must hit the result cache the second
/// time — and the cached answer must still be bit-identical.
#[test]
fn socket_cache_hit_path_serves_identical_results() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1)
            .fixed_batch(4)
            .queue_capacity(64)
            .cache_capacity(32)
            .retry_after_ms(1),
    )
    .unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    let mut rng = Rng::new(99);
    let x: Vec<f32> = (0..D_IN).map(|_| rng.normal_f32()).collect();
    let want = model.forward_vec(&x, 1, 1);

    let first = client.infer_retrying(1, &x, 50).unwrap();
    // the sync client saw the first response, so the insert has happened:
    // the replay below is a guaranteed cache hit
    let second = client.infer_retrying(1, &x, 50).unwrap();
    assert_bits_eq(&first, &want, "first (computed)");
    assert_bits_eq(&second, &want, "second (cached)");

    let stats = handle.stop();
    assert_eq!(stats.served, 1, "exactly one compute");
    assert_eq!(stats.cache_hits, 1, "replay served from cache");
}

/// With no workers draining (ingestion-only mode) a bounded queue fills
/// deterministically: the overflow request gets a well-formed Busy
/// response carrying the configured retry hint.
#[test]
fn socket_backpressure_returns_busy_when_queue_full() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(0) // nothing drains: push 3 will find a full queue
            .fixed_batch(4)
            .queue_capacity(2)
            .cache_capacity(0)
            .retry_after_ms(7),
    )
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr()).unwrap();
    let x = vec![0.5f32; D_IN];
    for id in 1..=3u64 {
        write_request(&mut stream, &RequestFrame { id, rows: 1, payload: x.clone() }).unwrap();
    }
    // requests 1 and 2 sit in the queue; 3 must bounce
    let resp = read_response(&mut stream).unwrap().expect("busy response");
    assert_eq!(resp.id, 3, "the overflowing request is the one rejected");
    assert_eq!(
        resp.body,
        ResponseBody::Busy { retry_after_ms: 7 },
        "well-formed retry response with the configured hint"
    );
    drop(stream);
    let stats = handle.stop();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.served, 0);
}

/// Trickle traffic must be served batch-1; pipelined flood traffic must
/// coalesce — observed forward sizes vary with offered load, which is the
/// adaptive batcher doing its job.
#[test]
fn socket_adaptive_batch_sizes_vary_with_load() {
    // Big dense layers: one forward costs ~100x a frame parse, so the
    // pipelined flood reliably outpaces the single worker and builds
    // queue depth for the EWMA to observe.
    let d_in = 256usize;
    let d_out = 128usize;
    let spec = |n, act| LayerSpec {
        n,
        repr: Repr::Dense,
        sparsity: 0.9,
        ablated_frac: 0.25,
        activation: act,
    };
    let model = Arc::new(
        SparseModel::synth(
            d_in,
            &[spec(512, Activation::Relu), spec(512, Activation::Relu), spec(d_out, Activation::Identity)],
            23,
        )
        .unwrap(),
    );
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1)
            .adaptive(8)
            .queue_capacity(512)
            .cache_capacity(0)
            // the flood below pipelines 300 responses against a client
            // that reads them all afterwards: give the egress room so
            // none convert to Busy while the client is still writing
            .egress_capacity(512)
            .retry_after_ms(1),
    )
    .unwrap();
    let addr = handle.addr();
    let mut rng = Rng::new(0xADA);

    // phase 1 — trickle: one request in flight at a time
    let mut client = Client::connect(addr).unwrap();
    for _ in 0..20 {
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal_f32()).collect();
        match client.infer(1, &x).unwrap() {
            Reply::Output(out) => assert_eq!(out.len(), d_out),
            Reply::Busy { .. } => panic!("trickle must never be rejected at queue cap 512"),
        }
    }

    // phase 2 — flood: pipeline 300 requests, then collect 300 responses
    let n_flood = 300usize;
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut payloads = Vec::with_capacity(n_flood);
    for id in 0..n_flood as u64 {
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal_f32()).collect();
        write_request(&mut stream, &RequestFrame { id, rows: 1, payload: x.clone() }).unwrap();
        payloads.push(x);
    }
    let mut answered = 0usize;
    for _ in 0..n_flood {
        let resp = read_response(&mut stream).unwrap().expect("flood response");
        match resp.body {
            ResponseBody::Output { rows, data } => {
                assert_eq!(rows, 1);
                let want = model.forward_vec(&payloads[resp.id as usize], 1, 1);
                assert_bits_eq(&data, &want, &format!("flood id {}", resp.id));
                answered += 1;
            }
            ResponseBody::Busy { .. } => panic!("flood of 300 fits queue cap 512"),
            ResponseBody::Error(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(answered, n_flood);
    drop(stream);

    let stats = handle.stop();
    assert_eq!(stats.served, 20 + n_flood);
    assert_eq!(stats.min_forward_rows, 1, "trickle phase ran batch-1 forwards");
    assert!(
        stats.max_forward_rows > 1,
        "flood phase must coalesce (max_forward_rows = {}, mean_batch = {:.2})",
        stats.max_forward_rows,
        stats.latency.mean_batch
    );
}

/// A slow client (pipelines a flood, then reads nothing) must not stall
/// other connections: pool workers push to the slow connection's bounded
/// egress queue instead of blocking on its socket, so a concurrent
/// well-behaved client keeps getting served by the SAME single worker.
/// Overflowed responses surface as Busy frames and the dropped-responses
/// counter.
#[test]
fn socket_slow_client_blocks_only_its_own_connection() {
    // Wide output (4096 f32 = 16 KiB per response frame): a 300-deep
    // unread flood is ~4.8 MiB of responses, far beyond what kernel
    // socket buffers can absorb, so the cap-2 egress queue must overflow
    // no matter how the host tunes its buffers.
    let d_out = 4096usize;
    let spec_narrow = LayerSpec {
        n: 48,
        repr: Repr::Condensed,
        sparsity: 0.9,
        ablated_frac: 0.25,
        activation: Activation::Relu,
    };
    let spec_wide = LayerSpec {
        n: d_out,
        repr: Repr::Dense,
        sparsity: 0.9,
        ablated_frac: 0.0,
        activation: Activation::Identity,
    };
    let model = Arc::new(SparseModel::synth(D_IN, &[spec_narrow, spec_wide], 31).unwrap());
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1) // a single worker: if it blocked on the slow
            // client's socket, the fast client below would starve
            .fixed_batch(4)
            .queue_capacity(512) // the whole flood fits: no ingress Busy
            .cache_capacity(0)
            .egress_capacity(2) // tiny egress: the flood must overflow it
            .retry_after_ms(3),
    )
    .unwrap();
    let addr = handle.addr();

    // slow client: pipeline the flood, read nothing yet. The worker parks
    // at most 2 computed responses in the egress (plus whatever the
    // kernel buffered); the rest convert to Busy or drop — without ever
    // blocking the worker.
    let n_slow = 300usize;
    let mut slow = TcpStream::connect(addr).unwrap();
    let x = vec![0.25f32; D_IN];
    for id in 1..=n_slow as u64 {
        write_request(&mut slow, &RequestFrame { id, rows: 1, payload: x.clone() }).unwrap();
    }
    slow.flush().unwrap();

    // fast client: must make steady progress while the flood is being
    // worked through by the same single worker.
    let mut fast = Client::connect(addr).unwrap();
    let mut rng = Rng::new(0x51);
    for req in 0..20usize {
        let xf: Vec<f32> = (0..D_IN).map(|_| rng.normal_f32()).collect();
        let got = fast.infer_retrying(1, &xf, 200).expect("fast client served");
        assert_bits_eq(&got, &model.forward_vec(&xf, 1, 1), &format!("fast req {req}"));
    }

    // now drain the slow connection: whatever arrives must be well-formed
    // (Output bit-exact or Busy), until the server's answers run out
    slow.set_read_timeout(Some(std::time::Duration::from_millis(500))).unwrap();
    let want = model.forward_vec(&x, 1, 1);
    let mut outputs = 0usize;
    let mut busies = 0usize;
    loop {
        match read_response(&mut slow) {
            Ok(Some(resp)) => match resp.body {
                ResponseBody::Output { rows, data } => {
                    assert_eq!(rows, 1);
                    assert_bits_eq(&data, &want, "slow client output");
                    outputs += 1;
                }
                ResponseBody::Busy { .. } => busies += 1,
                ResponseBody::Error(e) => panic!("unexpected error: {e}"),
            },
            _ => break, // timeout or EOF: nothing more is coming
        }
    }
    assert!(outputs >= 1, "some computed responses reach the slow client");
    drop(slow);
    drop(fast);

    let stats = handle.stop();
    assert_eq!(stats.connections_total, 2);
    assert_eq!(stats.rejected, 0, "the flood fits the ingress queue");
    assert_eq!(stats.served, n_slow + 20, "every request was computed — none stalled a worker");
    assert!(
        stats.dropped_responses > 0,
        "a cap-2 egress under a {n_slow}-deep unread 16KiB-response flood must overflow \
         (dropped_responses = {})",
        stats.dropped_responses
    );
    // the Busy frames the slow client saw are a subset of the recorded
    // overflow events (the rest were dropped past the headroom)
    assert!(busies <= stats.dropped_responses, "busies={busies} <= dropped");
    // every slow-connection response is accounted for: delivered Outputs
    // plus overflow events (Busy conversions + silent drops) = requests
    assert_eq!(outputs + stats.dropped_responses, n_slow);
}

/// Malformed requests are answered with Error and the connection stays
/// usable for well-formed follow-ups.
#[test]
fn socket_bad_request_answered_but_connection_survives() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1)
            .fixed_batch(4)
            .queue_capacity(64)
            .cache_capacity(0)
            .retry_after_ms(1),
    )
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr()).unwrap();

    // wrong width (payload is d+1 floats), zero rows, and oversized rows
    let bad = [
        RequestFrame { id: 1, rows: 1, payload: vec![0.0; D_IN + 1] },
        RequestFrame { id: 2, rows: 0, payload: vec![] },
        RequestFrame { id: 3, rows: 99, payload: vec![0.0; 99 * D_IN] },
    ];
    for req in &bad {
        write_request(&mut stream, req).unwrap();
        let resp = read_response(&mut stream).unwrap().expect("error response");
        assert_eq!(resp.id, req.id);
        assert!(
            matches!(resp.body, ResponseBody::Error(_)),
            "id {} should be rejected, got {:?}",
            req.id,
            resp.body
        );
    }

    // the same connection still serves a valid request
    let x = vec![0.25f32; D_IN];
    write_request(&mut stream, &RequestFrame { id: 4, rows: 1, payload: x.clone() }).unwrap();
    let resp = read_response(&mut stream).unwrap().expect("ok response");
    assert_eq!(resp.id, 4);
    match resp.body {
        ResponseBody::Output { rows, data } => {
            assert_eq!(rows, 1);
            assert_bits_eq(&data, &model.forward_vec(&x, 1, 1), "post-error request");
        }
        other => panic!("expected output, got {other:?}"),
    }
    drop(stream);
    let stats = handle.stop();
    assert_eq!(stats.bad_requests, 3);
    assert_eq!(stats.served, 1);
}

/// A frame with an unparseable length prefix must be counted as a bad
/// request AND answered with a best-effort Error response (id 0: the
/// request id was unreadable) before the server hangs up — the old reader
/// exited silently, leaving the client waiting forever.
#[test]
fn socket_framing_error_answered_and_counted() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1)
            .fixed_batch(4)
            .queue_capacity(64)
            .cache_capacity(0)
            .retry_after_ms(1),
    )
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr()).unwrap();

    // length prefix beyond MAX_FRAME_BYTES: InvalidData at the wire layer
    stream.write_all(&((MAX_FRAME_BYTES + 1) as u32).to_le_bytes()).unwrap();
    stream.flush().unwrap();

    let resp = read_response(&mut stream).unwrap().expect("framing-error response");
    assert_eq!(resp.id, 0, "no parseable request id -> id 0");
    match resp.body {
        ResponseBody::Error(msg) => {
            assert!(msg.contains("framing"), "diagnostic names the failure: {msg}")
        }
        other => panic!("expected Error, got {other:?}"),
    }
    // the server hangs up after a framing error
    assert!(read_response(&mut stream).unwrap().is_none(), "connection closed after the error");
    drop(stream);

    let stats = handle.stop();
    assert_eq!(stats.bad_requests, 1, "framing error counted");
    assert_eq!(stats.served, 0);
}

/// `shards: 2` swaps in the persistent shard team under the same socket
/// front-end: responses must still be bit-for-bit identical to the
/// replicated direct forward (the team computes the same arithmetic per
/// neuron, on the same long-lived threads for every request).
#[test]
fn socket_sharded_engine_matches_replicated_bits() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1) // parallelism lives inside the shard team
            .fixed_batch(4)
            .queue_capacity(64)
            .cache_capacity(16)
            .retry_after_ms(1)
            .shards(2),
    )
    .unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    let mut rng = Rng::new(0x5AAD);
    for req in 0..20usize {
        let rows = 1 + (req % 3);
        let x: Vec<f32> = (0..rows * D_IN).map(|_| rng.normal_f32()).collect();
        let got = client.infer_retrying(rows, &x, 50).expect("infer");
        let want = model.forward_vec(&x, rows, 1);
        assert_bits_eq(&got, &want, &format!("sharded req {req} rows {rows}"));
    }
    let stats = handle.stop();
    assert_eq!(stats.served + stats.cache_hits, 20);
    assert_eq!(stats.bad_requests, 0);
}

/// A full wire-mode arena duel over the loopback front-end: both sides
/// replay the same cache-adversarial trace through real sockets and the
/// retrying client. Every request must get answered (the retry budget
/// covers transient Busy), both sides must serve the identical schedule,
/// and the summary must carry the front-end counters.
#[test]
fn socket_arena_wire_duel() {
    use srigl::arena::{run_duel, DuelConfig, Scenario, Trace, TraceSpec};

    let model = test_model(Repr::Condensed);
    let trace = Trace::generate(&TraceSpec {
        scenario: Scenario::Adversarial,
        n_requests: 80,
        mean_gap_us: 100.0,
        max_rows: 4,
        pool: 8,
        seed: 13,
    });
    let a = EngineBuilder::new()
        .workers(1)
        .fixed_batch(8)
        .queue_capacity(256)
        .cache_capacity(64)
        .retry_after_ms(1);
    let b = EngineBuilder::new()
        .workers(2)
        .adaptive(8)
        .queue_capacity(256)
        .cache_capacity(64)
        .retry_after_ms(1);
    let cfg = DuelConfig { rounds: 2, wire: true, clients: 3, max_retries: 50 };
    let summary =
        run_duel(&model, ("w1-fixed", &a), ("w2-adaptive", &b), &trace, &cfg, |_| {}).unwrap();

    assert_eq!(summary.paired, 2 * 80, "every request answered on both sides, both rounds");
    let j = summary.to_json();
    for side in ["a", "b"] {
        let rounds = j.get(side).unwrap().get("rounds").unwrap();
        let srigl::util::json::Json::Arr(rounds) = rounds else { panic!("rounds not an array") };
        assert_eq!(rounds.len(), 2);
        for round in rounds {
            assert_eq!(round.get("served").unwrap().as_usize().unwrap(), 80);
            let fe = round.get("frontend").unwrap();
            // adversarial payloads are unique: the result cache never hits
            assert_eq!(fe.get("cache_hits").unwrap().as_usize().unwrap(), 0);
            assert_eq!(fe.get("bad_requests").unwrap().as_usize().unwrap(), 0);
            // the legacy key and its split successors agree
            assert_eq!(fe.get("connections").unwrap().as_usize().unwrap(), 3);
            assert_eq!(fe.get("connections_total").unwrap().as_usize().unwrap(), 3);
            assert_eq!(fe.get("connections_active").unwrap().as_usize().unwrap(), 0);
            // every wire round persists a /metrics scrape consistent with
            // the front-end counters (adversarial trace: no cache hits)
            let m = round.get("metrics").unwrap();
            assert_eq!(
                m.get("srigl_requests_served_total").unwrap().as_f64().unwrap() as usize,
                80,
                "scraped served counter matches the round"
            );
            assert_eq!(m.get("srigl_connections_total").unwrap().as_f64().unwrap() as usize, 3);
        }
    }
}

/// The `/metrics` endpoint scrapes live while requests are in flight:
/// counters are monotonic across scrapes, agree exactly with the answered
/// request count at each quiescent point, and the final `FrontendStats`
/// match the last scrape. Per-layer engine facts ride along.
#[test]
fn socket_metrics_endpoint_scrapes_live_and_matches_final_stats() {
    use srigl::obs::{parse_exposition, scrape};

    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn_with_metrics(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(2)
            .adaptive(8)
            .queue_capacity(256)
            .cache_capacity(0) // every request computes: served is exact
            .retry_after_ms(1),
        Some("127.0.0.1:0"),
    )
    .unwrap();
    let maddr = handle.metrics_addr().expect("metrics endpoint requested at spawn");

    let mut client = Client::connect(handle.addr()).unwrap();
    let mut rng = Rng::new(0xB0B);
    let mut fire = |client: &mut Client, n: usize| {
        for _ in 0..n {
            let x: Vec<f32> = (0..D_IN).map(|_| rng.normal_f32()).collect();
            client.infer_retrying(1, &x, 50).expect("infer");
        }
    };
    fire(&mut client, 10);
    let s1 = parse_exposition(&scrape(maddr).unwrap());
    fire(&mut client, 15);
    let text2 = scrape(maddr).unwrap();
    let s2 = parse_exposition(&text2);

    // the sync client has every answer before each scrape, so the served
    // counter is exact (and monotonic across scrapes)
    let served = |s: &srigl::util::json::Json| {
        s.get("srigl_requests_served_total").unwrap().as_f64().unwrap() as usize
    };
    assert_eq!(served(&s1), 10);
    assert_eq!(served(&s2), 25);
    assert_eq!(
        s2.get("srigl_connections_active").unwrap().as_f64().unwrap() as usize,
        1,
        "the client is still connected at scrape time"
    );
    // the stage=total histogram saw exactly the served requests
    assert_eq!(
        s2.get("srigl_stage_latency_us_count{stage=\"total\"}").unwrap().as_f64().unwrap()
            as usize,
        25
    );
    // one series from every exported counter family, plus engine facts
    for needle in [
        "srigl_forward_batches_total",
        "srigl_cache_hits_total",
        "srigl_requests_rejected_total",
        "srigl_bad_requests_total",
        "srigl_dropped_responses_total",
        "srigl_connections_total",
        "srigl_connections_rejected_total",
        "srigl_forward_rows_min",
        "srigl_forward_rows_max",
        "srigl_engine_storage_bytes",
    ] {
        assert!(s2.get(needle).is_ok(), "{needle} missing from the exposition");
    }
    assert!(text2.contains("srigl_kernel_info{"), "kernel selection fact");
    assert!(
        text2.contains("srigl_layer_stored_weights{layer=\"0\",repr=\"condensed\"}"),
        "per-layer facts"
    );
    assert!(text2.contains("srigl_layer_est_gflops{"), "per-layer throughput estimate");
    assert!(
        text2.contains("srigl_stage_latency_us_bucket{stage=\"forward\",le=\"+Inf\"}"),
        "stage histogram exports cumulative buckets"
    );

    drop(client);
    let stats = handle.stop();
    assert_eq!(stats.served, 25, "final stats agree with the last scrape");
    assert_eq!(stats.connections_total, 1);
    assert_eq!(stats.connections_active, 0, "reader exit released the live-connection gauge");
}

/// With `max_connections: 1`, a second concurrent connection is refused at
/// accept with a well-formed Busy frame (id 0 — no request was read) and
/// then closed; once the first client hangs up, the slot frees and a new
/// connection is admitted. Refusals are counted separately from admits.
#[test]
fn socket_connection_cap_refuses_then_readmits() {
    let model = test_model(Repr::Condensed);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1)
            .fixed_batch(4)
            .queue_capacity(64)
            .cache_capacity(0)
            .retry_after_ms(9)
            .max_connections(1),
    )
    .unwrap();
    let addr = handle.addr();
    let x = vec![0.5f32; D_IN];

    // client A takes the only slot and is served normally
    let mut a = Client::connect(addr).unwrap();
    let got = a.infer_retrying(1, &x, 50).expect("admitted client served");
    assert_bits_eq(&got, &model.forward_vec(&x, 1, 1), "client A");

    // client B is over the cap: Busy with the configured hint, then EOF
    let mut b = TcpStream::connect(addr).unwrap();
    let resp = read_response(&mut b).unwrap().expect("refusal frame");
    assert_eq!(resp.id, 0, "no request was read — the refusal uses the control id");
    assert_eq!(resp.body, ResponseBody::Busy { retry_after_ms: 9 });
    assert!(read_response(&mut b).unwrap().is_none(), "refused connection is closed");
    drop(b);

    // after A hangs up the slot frees; a retrying connect gets admitted
    // (the reader notices EOF asynchronously, hence the retry loop)
    drop(a);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let mut c = TcpStream::connect(addr).unwrap();
        // the server may refuse-and-shutdown before this write lands
        let _ = write_request(&mut c, &RequestFrame { id: 7, rows: 1, payload: x.clone() });
        match read_response(&mut c) {
            Ok(Some(resp)) if resp.id == 7 => {
                match resp.body {
                    ResponseBody::Output { rows, data } => {
                        assert_eq!(rows, 1);
                        assert_bits_eq(&data, &model.forward_vec(&x, 1, 1), "readmitted client");
                    }
                    other => panic!("expected output after readmission, got {other:?}"),
                }
                break;
            }
            _ => {
                // still refused (Busy id 0, EOF, or broken pipe)
                assert!(
                    std::time::Instant::now() < deadline,
                    "slot never freed after the first client hung up"
                );
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        }
    }

    let stats = handle.stop();
    assert_eq!(stats.served, 2, "one request from A, one from the readmitted client");
    assert_eq!(stats.connections_total, 2, "only A and the readmitted client were admitted");
    assert!(stats.connections_rejected >= 1, "client B (at least) was refused");
    assert_eq!(stats.bad_requests, 0);
}

/// Same dims as [`test_model`] but a different seed: a distinct stack to
/// swap in, whose outputs differ so cross-epoch mixes cannot hide.
fn test_model_seed(repr: Repr, seed: u64) -> Arc<SparseModel> {
    let spec = |n, act| LayerSpec {
        n,
        repr,
        sparsity: 0.9,
        ablated_frac: 0.25,
        activation: act,
    };
    Arc::new(
        SparseModel::synth(
            D_IN,
            &[
                spec(48, Activation::Relu),
                spec(32, Activation::Relu),
                spec(D_OUT, Activation::Identity),
            ],
            seed,
        )
        .unwrap(),
    )
}

/// The epoch conformance bar, over real sockets: a swap lands while 3
/// client threads flood a cache-enabled front-end with a small payload
/// pool (maximizing cache traffic), and every single response is
/// bit-for-bit one epoch's oracle — never a mix. After the flood, replays
/// of the pool must all serve the NEW stack: a cross-epoch cache hit
/// would surface here as an old-epoch answer, bit-exactly caught.
#[test]
fn socket_swap_mid_flood_never_mixes_epochs() {
    let m0 = test_model(Repr::Condensed);
    let m1 = test_model_seed(Repr::Condensed, 29);
    let handle = frontend::spawn_swappable(
        Arc::clone(&m0),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(2)
            .adaptive(4)
            .queue_capacity(256)
            .cache_capacity(64) // cache ON: the generation check is under test
            .retry_after_ms(1),
        None,
        None,
    )
    .unwrap();
    let addr = handle.addr();

    // Small payload pool, reused by every thread: lots of cache hits.
    let mut rng = Rng::new(0x3CA9);
    let pool: Vec<Vec<f32>> =
        (0..6).map(|_| (0..D_IN).map(|_| rng.normal_f32()).collect()).collect();
    let oracle0: Vec<Vec<f32>> = pool.iter().map(|x| m0.forward_vec(x, 1, 1)).collect();
    let oracle1: Vec<Vec<f32>> = pool.iter().map(|x| m1.forward_vec(x, 1, 1)).collect();
    let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();

    let n_per_client = 40usize;
    std::thread::scope(|s| {
        for t in 0..3usize {
            let (pool, oracle0, oracle1) = (&pool, &oracle0, &oracle1);
            s.spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                for req in 0..n_per_client {
                    let pi = (req + t) % pool.len();
                    let got = client.infer_retrying(1, &pool[pi], 50).expect("infer");
                    let is0 = bits(&got) == bits(&oracle0[pi]);
                    let is1 = bits(&got) == bits(&oracle1[pi]);
                    assert!(
                        is0 ^ is1,
                        "client {t} req {req}: response must be exactly one epoch's \
                         oracle (old={is0} new={is1}) — never a mix"
                    );
                }
            });
        }
        // Mid-flood: publish the new stack while all 3 clients hammer.
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(handle.publish_model(Arc::clone(&m1)).unwrap(), 1, "swap lands mid-flood");
    });

    // Quiescent replay of the whole pool: every answer must now be the
    // new stack's — a stale cache entry (epoch-0 generation) serving here
    // would be a cross-epoch cache hit.
    let mut client = Client::connect(addr).unwrap();
    for (pi, x) in pool.iter().enumerate() {
        let got = client.infer_retrying(1, x, 50).unwrap();
        assert_bits_eq(&got, &oracle1[pi], &format!("post-swap replay payload {pi}"));
    }
    drop(client);

    let stats = handle.stop();
    assert_eq!(stats.connections_total, 4, "3 flood clients + 1 replay client");
    assert_eq!(stats.connections_active, 0, "swap must not leak connection accounting");
    assert_eq!(
        stats.served + stats.cache_hits,
        3 * n_per_client + pool.len(),
        "every request answered exactly once across the swap (rejected={})",
        stats.rejected
    );
    assert_eq!(stats.bad_requests, 0);
}

/// The wire reload path end to end: a control frame makes the server pull
/// the next stack from its [`frontend::ReloadSource`], answers with the
/// new epoch id, and subsequent inference serves the new stack. The
/// `/metrics` endpoint tracks `srigl_model_epoch` and exports the new
/// depth gauges. A server spawned without reload support answers the
/// control frame with a well-formed Error and the connection survives.
#[test]
fn socket_wire_reload_bumps_epoch_and_gauges() {
    use srigl::obs::{parse_exposition, scrape};
    use std::sync::atomic::{AtomicUsize, Ordering};

    const SEEDS: [u64; 3] = [17, 29, 43];
    let models: Vec<Arc<SparseModel>> =
        SEEDS.iter().map(|&s| test_model_seed(Repr::Condensed, s)).collect();

    let calls = Arc::new(AtomicUsize::new(0));
    let source: frontend::ReloadSource = {
        let models = models.clone();
        let calls = Arc::clone(&calls);
        Box::new(move || {
            let i = 1 + calls.fetch_add(1, Ordering::Relaxed);
            Ok(Arc::clone(&models[i % models.len()]))
        })
    };
    let handle = frontend::spawn_swappable(
        Arc::clone(&models[0]),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(1)
            .fixed_batch(4)
            .queue_capacity(64)
            .cache_capacity(16)
            .retry_after_ms(1),
        Some("127.0.0.1:0"),
        Some(source),
    )
    .unwrap();
    let maddr = handle.metrics_addr().expect("metrics endpoint requested at spawn");

    let mut client = Client::connect(handle.addr()).unwrap();
    let mut rng = Rng::new(0xE11);
    let x: Vec<f32> = (0..D_IN).map(|_| rng.normal_f32()).collect();

    let got = client.infer_retrying(1, &x, 50).unwrap();
    assert_bits_eq(&got, &models[0].forward_vec(&x, 1, 1), "epoch 0 serves the boot stack");
    let s0 = parse_exposition(&scrape(maddr).unwrap());
    assert_eq!(s0.get("srigl_model_epoch").unwrap().as_f64().unwrap() as u64, 0);

    // Wire reload #1: the server pulls models[1] and publishes epoch 1.
    assert_eq!(client.reload().expect("wire reload"), 1);
    let got = client.infer_retrying(1, &x, 50).unwrap();
    assert_bits_eq(&got, &models[1].forward_vec(&x, 1, 1), "epoch 1 serves the reloaded stack");

    // Wire reload #2 over the same connection.
    assert_eq!(client.reload().expect("second wire reload"), 2);
    let got = client.infer_retrying(1, &x, 50).unwrap();
    assert_bits_eq(&got, &models[2].forward_vec(&x, 1, 1), "epoch 2");

    let text = scrape(maddr).unwrap();
    let s = parse_exposition(&text);
    assert_eq!(s.get("srigl_model_epoch").unwrap().as_f64().unwrap() as u64, 2, "gauge tracks");
    assert!(s.get("srigl_queue_depth").is_ok(), "ingress depth gauge exported");
    assert!(
        text.contains("srigl_egress_depth{conn="),
        "per-connection egress depth gauge exported while the client is live"
    );
    // Facts were republished for the new epoch, not the dead boot stack.
    assert!(text.contains("srigl_layer_stored_weights{"), "per-layer facts survive reload");

    drop(client);
    let stats = handle.stop();
    assert_eq!(stats.served + stats.cache_hits, 3, "controls are not served requests");
    assert_eq!(stats.bad_requests, 0, "a supported control frame is not a bad request");
    assert_eq!(calls.load(Ordering::Relaxed), 2, "one source pull per reload");

    // Control frames against a non-reloadable spawn: well-formed Error,
    // connection survives.
    let m = test_model(Repr::Condensed);
    let plain = frontend::spawn(
        Arc::clone(&m),
        "127.0.0.1:0",
        &EngineBuilder::new().workers(1).fixed_batch(4).queue_capacity(64).cache_capacity(0),
    )
    .unwrap();
    let mut client = Client::connect(plain.addr()).unwrap();
    let err = client.reload().expect_err("immutable spawn must refuse reload");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidInput, "{err}");
    let got = client.infer_retrying(1, &x, 50).expect("connection survives the refusal");
    assert_bits_eq(&got, &m.forward_vec(&x, 1, 1), "post-refusal inference");
    drop(client);
    plain.stop();
}

/// Multi-row requests round-trip with row-major layout preserved.
#[test]
fn socket_multi_row_request_roundtrips() {
    let model = test_model(Repr::Structured);
    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(2)
            .adaptive(8)
            .queue_capacity(64)
            .cache_capacity(16)
            .retry_after_ms(1),
    )
    .unwrap();
    let mut client = Client::connect(handle.addr()).unwrap();
    let mut rng = Rng::new(5);
    for rows in [2usize, 5, 8] {
        let x: Vec<f32> = (0..rows * D_IN).map(|_| rng.normal_f32()).collect();
        let got = client.infer_retrying(rows, &x, 50).unwrap();
        assert_eq!(got.len(), rows * D_OUT);
        assert_bits_eq(&got, &model.forward_vec(&x, rows, 1), &format!("rows {rows}"));
    }
    handle.stop();
}
