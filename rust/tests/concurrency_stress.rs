//! Std-threaded stress mirrors of the loom models.
//!
//! Each test replays a `rust/tests/loom_models.rs` scenario with real OS
//! parallelism at a scale loom cannot reach (4 threads × 1000
//! iterations): loom proves the invariant over ALL interleavings of a
//! tiny schedule, these tests hammer ONE large schedule on real hardware
//! where weak-memory effects and genuine contention exist. The pairing is
//! deliberate — a failure here with a green loom run points at something
//! outside the model (memory ordering, a scale-dependent path), which is
//! exactly the triage signal docs/ANALYSIS.md documents.
//!
//! Excluded from `--cfg loom` builds: these use std threads/atomics
//! directly and would be meaningless (and non-compiling) under the
//! mocked runtime.
#![cfg(not(loom))]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use srigl::inference::engine::{DoneLatch, EpochCell, Mailbox};
use srigl::inference::frontend::{Egress, SendOutcome};
use srigl::net::{ResponseBody, ResponseFrame};
use srigl::util::threadpool::Injector;

const THREADS: usize = 4;
const ITERS: usize = 1000;

fn out_frame(id: u64) -> ResponseFrame {
    ResponseFrame { id, body: ResponseBody::Output { rows: 1, data: vec![1.0] } }
}

/// Mirror of `injector_bounded_counts_every_item_once`: 4 producers race
/// 1000 bounded pushes each against a draining consumer on a capacity-8
/// queue; the accepted/rejected/consumed conservation law must hold at
/// full contention.
#[test]
fn stress_injector_bounded_conservation() {
    let inj: Arc<Injector<u64>> = Arc::new(Injector::with_capacity(8));
    let accepted = Arc::new(AtomicU64::new(0));
    let producers: Vec<_> = (0..THREADS)
        .map(|t| {
            let (inj, accepted) = (Arc::clone(&inj), Arc::clone(&accepted));
            thread::spawn(move || {
                for i in 0..ITERS {
                    if inj.push_bounded((t * ITERS + i) as u64).is_ok() {
                        accepted.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    let consumer = {
        let inj = Arc::clone(&inj);
        thread::spawn(move || {
            let (mut consumed, mut buf) = (0u64, Vec::new());
            loop {
                buf.clear();
                let n = inj.pop_batch(16, &mut buf);
                if n == 0 {
                    break;
                }
                consumed += n as u64;
            }
            consumed
        })
    };
    for p in producers {
        p.join().unwrap();
    }
    inj.close();
    let consumed = consumer.join().unwrap();
    assert_eq!(consumed, accepted.load(Ordering::Relaxed), "every accepted item consumed once");
}

/// Mirror of `egress_overflow_headroom_counting`: 4 workers push 1000
/// responses each through a small egress while the writer drains; the
/// outcome tally must account for every frame and the writer must receive
/// exactly the enqueued ones.
#[test]
fn stress_egress_overflow_conservation() {
    let e = Arc::new(Egress::with_headroom(8, 4, 7));
    let (queued, busy, dropped) =
        (Arc::new(AtomicU64::new(0)), Arc::new(AtomicU64::new(0)), Arc::new(AtomicU64::new(0)));
    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let e = Arc::clone(&e);
            let (queued, busy, dropped) =
                (Arc::clone(&queued), Arc::clone(&busy), Arc::clone(&dropped));
            thread::spawn(move || {
                for i in 0..ITERS {
                    e.job_started();
                    match e.send(out_frame((t * ITERS + i) as u64)) {
                        SendOutcome::Queued => queued.fetch_add(1, Ordering::Relaxed),
                        SendOutcome::ConvertedBusy => busy.fetch_add(1, Ordering::Relaxed),
                        SendOutcome::Dropped => dropped.fetch_add(1, Ordering::Relaxed),
                        SendOutcome::Gone => panic!("egress closed while jobs in flight"),
                    };
                    e.job_finished();
                }
            })
        })
        .collect();
    let writer = {
        let e = Arc::clone(&e);
        thread::spawn(move || {
            let mut received = 0u64;
            while e.recv().is_some() {
                received += 1;
            }
            received
        })
    };
    for w in workers {
        w.join().unwrap();
    }
    e.reader_done();
    let received = writer.join().unwrap();
    let (q, b, d) =
        (queued.load(Ordering::Relaxed), busy.load(Ordering::Relaxed), dropped.load(Ordering::Relaxed));
    assert_eq!(q + b + d, (THREADS * ITERS) as u64, "every send has exactly one outcome");
    assert_eq!(received, q + b, "writer drains exactly the enqueued frames");
}

/// Mirror of `epoch_shadow_never_leads_published`: one publisher walks
/// the epoch through 1000 generations while 4 readers continuously check
/// shadow-vs-snapshot coherence under real parallelism.
#[test]
fn stress_epoch_shadow_coherence() {
    let cell = Arc::new(EpochCell::new(0, Arc::new(0u64)));
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..THREADS)
        .map(|_| {
            let (cell, stop) = (Arc::clone(&cell), Arc::clone(&stop));
            thread::spawn(move || {
                let mut checks = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let shadow = cell.epoch();
                    let (id, v) = cell.current();
                    assert!(id >= shadow, "snapshot id {id} older than peeked shadow {shadow}");
                    assert_eq!(*v, id, "snapshot pairs id with that id's stack");
                    checks += 1;
                }
                checks
            })
        })
        .collect();
    for id in 1..=ITERS as u64 {
        cell.publish(id, Arc::new(id)).unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        assert!(r.join().unwrap() > 0, "reader made progress");
    }
    assert_eq!(cell.epoch(), ITERS as u64);
}

/// A probe job mirroring the loom mailbox models' use-after-free
/// detector: raw pointer into the coordinator's stack plus a liveness
/// flag cleared once the latch releases the coordinator.
enum ProbeJob {
    Run { data: *const u64, valid: Arc<AtomicBool> },
    Stop,
}

// SAFETY: `data` is only dereferenced while the posting coordinator
// blocks on the completion latch, which keeps the pointed-to stack slot
// alive; the `valid` flag turns any violation of that protocol into a
// deterministic assertion failure instead of UB.
unsafe impl Send for ProbeJob {}

/// Mirror of the two mailbox/latch models at scale: 2 shards × 1000
/// rounds of post → run → arrive → reset, with the use-after-free probe
/// armed on every round.
#[test]
fn stress_mailbox_latch_rounds() {
    const SHARDS: usize = 2;
    let mbs: Vec<Arc<Mailbox<ProbeJob>>> = (0..SHARDS).map(|_| Arc::new(Mailbox::new())).collect();
    let latch = Arc::new(DoneLatch::new());
    let sum = Arc::new(AtomicU64::new(0));
    let shards: Vec<_> = mbs
        .iter()
        .map(|mb| {
            let (mb, latch, sum) = (Arc::clone(mb), Arc::clone(&latch), Arc::clone(&sum));
            thread::spawn(move || loop {
                match mb.take() {
                    ProbeJob::Stop => return,
                    ProbeJob::Run { data, valid } => {
                        assert!(
                            valid.load(Ordering::SeqCst),
                            "use-after-free: shard dereferenced a reclaimed job"
                        );
                        // SAFETY: the coordinator is blocked on the latch
                        // until `arrive` below, so `data`'s stack slot is
                        // still alive here.
                        sum.fetch_add(unsafe { *data }, Ordering::SeqCst);
                        latch.arrive();
                    }
                }
            })
        })
        .collect();
    let mut expect = 0u64;
    for round in 1..=ITERS as u64 {
        let x: u64 = round; // stack storage the jobs point into
        let valid = Arc::new(AtomicBool::new(true));
        for mb in &mbs {
            mb.put(ProbeJob::Run { data: &x, valid: Arc::clone(&valid) });
        }
        latch.wait_and_reset(SHARDS);
        valid.store(false, Ordering::SeqCst); // x is dead to the shards now
        expect += SHARDS as u64 * round;
    }
    for mb in &mbs {
        mb.put(ProbeJob::Stop);
    }
    for s in shards {
        s.join().unwrap();
    }
    assert_eq!(sum.load(Ordering::SeqCst), expect, "every round ran on every shard exactly once");
}
