//! Int8 quantized serving-path suite: the quantized condensed pair
//! ([`srigl::inference::QuantizedLayer`] /
//! [`srigl::inference::QuantizedTiledLayer`]) must
//!
//! * stay within the **documented per-row error budget** against the f32
//!   condensed oracle (`QuantizedCondensed::row_error_bound`, derived in
//!   docs/KERNELS.md) — across ragged batch sizes {1, 7, 8, 256}, thread
//!   counts, and a heavy-ablation geometry;
//! * be **bit-for-bit identical** between the row-gather and batch-tiled
//!   drivers and across every available kernel kind (i32 accumulation is
//!   exact, so unlike the f32 family there is no ULP allowance at all);
//! * **round-trip** calibration: requantizing the dequantized twin
//!   reproduces the integer records exactly;
//! * degrade cleanly at the k=0 / all-ablated edge and compose into
//!   whole-model quantized twins (`SparseModel::quantized` == a stack
//!   built directly with `Repr::Quantized`).

use srigl::inference::model::{Activation, LayerSpec, Repr, SparseModel};
use srigl::inference::{LayerBundle, LinearKernel, QuantizedLayer, QuantizedTiledLayer};
use srigl::kernels::{KernelKind, Microkernel};
use srigl::sparsity::{Mask, QuantizedCondensed};
use srigl::tensor::Tensor;
use srigl::util::rng::Rng;

/// Ragged batches around the tile width 8, plus the serving-scale batch
/// the bench duels at.
const BATCHES: [usize; 4] = [1, 7, 8, 256];

/// (n, d, sparsity, ablated_frac, seed) — ordinary, tall-thin, and a
/// heavy-ablation geometry (85% of neurons gone).
const GEOMETRIES: [(usize, usize, f64, f64, u64); 3] =
    [(64, 128, 0.9, 0.25, 1), (33, 77, 0.95, 0.1, 3), (40, 64, 0.9, 0.85, 4)];

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// Per-element error budget check of `got` (quantized) against `want`
/// (f32 condensed oracle). The documented bound covers weight residual +
/// activation rounding; pure f32 *evaluation* slop (the i32->f32
/// accumulator cast above 2^24, the finalize multiply) is excluded from
/// the derivation, so the assertion adds a 1% relative cushion and a
/// small absolute epsilon.
fn assert_within_budget(
    q: &QuantizedCondensed,
    x: &[f32],
    batch: usize,
    got: &[f32],
    want: &[f32],
    ctx: &str,
) {
    let d = q.d;
    let na = q.n_active();
    assert_eq!(got.len(), batch * na, "{ctx}: output shape");
    for b in 0..batch {
        let xmax = x[b * d..(b + 1) * d].iter().fold(0f32, |m, &v| m.max(v.abs()));
        for r in 0..na {
            let bound = q.row_error_bound(r, xmax) * 1.01 + 1e-5;
            let (g, w) = (got[b * na + r], want[b * na + r]);
            assert!(
                (g - w).abs() <= bound,
                "{ctx}: batch row {b}, active row {r}: quantized {g} vs oracle {w} \
                 (|diff| {} > budget {bound})",
                (g - w).abs()
            );
        }
    }
}

#[test]
fn quantized_outputs_stay_within_documented_error_budget() {
    for &(n, d, sparsity, ablated, seed) in &GEOMETRIES {
        let bundle = LayerBundle::synth(n, d, sparsity, ablated, seed);
        let q = &bundle.quantized.q;
        let na = q.n_active();
        for &batch in &BATCHES {
            let mut rng = Rng::new(seed ^ 0xbad5eed);
            let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();
            let mut want = vec![0f32; batch * na];
            bundle.condensed.forward(&x, batch, &mut want, 1);
            for &threads in &[1usize, 4] {
                let ctx = format!("n{n} d{d} abl{ablated} b{batch} t{threads}");
                let mut got = vec![0f32; batch * na];
                bundle.quantized.forward(&x, batch, &mut got, threads);
                assert_within_budget(q, &x, batch, &got, &want, &format!("{ctx} rows"));
                let mut got_t = vec![0f32; batch * na];
                bundle.quantized_tiled.forward(&x, batch, &mut got_t, threads);
                assert_within_budget(q, &x, batch, &got_t, &want, &format!("{ctx} tiled"));
                // the two drivers share exact integer accumulation: no
                // tolerance between them, ever
                assert_eq!(bits(&got), bits(&got_t), "{ctx}: row vs tiled must be bit-for-bit");
            }
        }
    }
}

#[test]
fn quantized_is_bitwise_invariant_across_kernel_kinds() {
    // The f32 family pins SIMD-vs-scalar to a ULP bound; the int8 family
    // must be exactly equal: every kind computes the same i32
    // accumulators and shares one finalize.
    let (n, d, sparsity, ablated, seed) = GEOMETRIES[0];
    let bundle = LayerBundle::synth(n, d, sparsity, ablated, seed);
    let na = bundle.quantized.q.n_active();
    let scalar = Microkernel::of(KernelKind::Scalar);
    for &batch in &BATCHES {
        let mut rng = Rng::new(0x51 ^ batch as u64);
        let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();
        for (label, layer) in [
            ("quantized", &bundle.quantized as &dyn LinearKernel),
            ("quantized-tiled", &bundle.quantized_tiled as &dyn LinearKernel),
        ] {
            let mut want = vec![0f32; batch * na];
            layer.with_kernel(scalar).forward(&x, batch, &mut want, 1);
            for kind in KernelKind::ALL {
                if !kind.available() {
                    continue;
                }
                for &threads in &[1usize, 4] {
                    let mut got = vec![0f32; batch * na];
                    layer.with_kernel(Microkernel::of(kind)).forward(&x, batch, &mut got, threads);
                    assert_eq!(
                        bits(&got),
                        bits(&want),
                        "{label} {} b{batch} t{threads} must match the scalar oracle exactly",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn calibration_round_trips_through_the_dequantized_twin() {
    for &(n, d, sparsity, ablated, seed) in &GEOMETRIES {
        let bundle = LayerBundle::synth(n, d, sparsity, ablated, seed);
        let q = &bundle.quantized.q;
        // quantize(dequantize(q)) reproduces the integer records exactly:
        // the dequantized values s*q_i rescale to integers with error far
        // below the rounding threshold
        let twin = QuantizedCondensed::from_condensed(&q.dequantize()).unwrap();
        assert_eq!(twin.recs, q.recs, "integer records must round-trip exactly");
        assert_eq!(twin.active, q.active);
        assert_eq!((twin.d, twin.n_orig, twin.k), (q.d, q.n_orig, q.k));
        // the recalibrated scale may differ from the original only by f32
        // rounding of identical least-squares sums
        for r in 0..q.n_active() {
            let (a, b) = (q.scales[r], twin.scales[r]);
            assert!(
                (a - b).abs() <= 1e-6 * a.abs().max(b.abs()),
                "row {r}: scale {a} vs requantized {b}"
            );
        }
        // and the twin's weight residual is (up to the same rounding) zero
        for r in 0..twin.n_active() {
            assert!(
                twin.resid_l1[r] <= 1e-5 * (1.0 + twin.qabs_l1[r]),
                "row {r}: requantizing exact multiples must leave ~no residual, got {}",
                twin.resid_l1[r]
            );
        }
    }
}

#[test]
fn all_ablated_quantized_layer_forwards_empty() {
    // k=0 edge: an all-ablated layer must construct and serve an empty
    // forward through both quantized drivers, mirroring the f32 pair.
    let (n, d) = (6usize, 10usize);
    let w = Tensor::zeros(&[n, d]);
    let m = Mask::from_tensor(Tensor::zeros(&[n, d]));
    let bias = vec![1.0f32; n];
    let layer = QuantizedLayer::new(&w, &m, &bias).unwrap();
    let tiled = QuantizedTiledLayer::new(&w, &m, &bias).unwrap();
    assert_eq!(LinearKernel::out_width(&layer), 0);
    assert_eq!(LinearKernel::out_width(&tiled), 0);
    for batch in [1usize, 3, 9] {
        let x = vec![0.5f32; batch * d];
        let mut out: Vec<f32> = vec![];
        LinearKernel::forward(&layer, &x, batch, &mut out, 2);
        assert!(out.is_empty());
        LinearKernel::forward(&tiled, &x, batch, &mut out, 2);
        assert!(out.is_empty());
    }
    assert_eq!(layer.q.storage_bytes(), 0);
    assert_eq!(tiled.q.storage_bytes(), 0);
}

fn stack(repr: Repr, seed: u64) -> SparseModel {
    let spec = |n, act| LayerSpec {
        n,
        repr,
        sparsity: 0.9,
        ablated_frac: 0.25,
        activation: act,
    };
    SparseModel::synth(
        64,
        &[spec(48, Activation::Relu), spec(32, Activation::Relu), spec(16, Activation::Identity)],
        seed,
    )
    .unwrap()
}

#[test]
fn model_level_quantized_twin_matches_direct_construction() {
    // `SparseModel::quantized` on a condensed stack must equal the stack
    // built directly with Repr::Quantized from identical weights —
    // quantization is deterministic, so bit-for-bit, for both drivers.
    let f32_stack = stack(Repr::Condensed, 7);
    for (tiled, repr) in [(false, Repr::Quantized), (true, Repr::QuantizedTiled)] {
        let twin = f32_stack.quantized(tiled).unwrap();
        let direct = stack(repr, 7);
        assert!(twin.storage_bytes() < f32_stack.storage_bytes(), "int8 must shrink the stack");
        assert_eq!(twin.storage_bytes(), direct.storage_bytes());
        for batch in [1usize, 7, 8] {
            let mut rng = Rng::new(0xD0 ^ batch as u64);
            let x: Vec<f32> = (0..batch * 64).map(|_| rng.normal_f32()).collect();
            assert_eq!(
                bits(&twin.forward_vec(&x, batch, 1)),
                bits(&direct.forward_vec(&x, batch, 1)),
                "twin vs direct (tiled={tiled}) b{batch}"
            );
        }
    }
    // non-condensed stacks refuse with a typed startup error
    assert!(stack(Repr::Dense, 7).quantized(false).is_err());
    assert!(stack(Repr::Csr, 7).quantized(true).is_err());
    // quantizing an already-quantized stack is idempotent
    let q = f32_stack.quantized(false).unwrap();
    let qq = q.quantized(false).unwrap();
    let x: Vec<f32> = {
        let mut rng = Rng::new(9);
        (0..2 * 64).map(|_| rng.normal_f32()).collect()
    };
    assert_eq!(bits(&q.forward_vec(&x, 2, 1)), bits(&qq.forward_vec(&x, 2, 1)));
}

#[test]
fn repr_parse_round_trips_quantized_names() {
    for (s, repr) in [
        ("quantized", Repr::Quantized),
        ("quant", Repr::Quantized),
        ("quantized-tiled", Repr::QuantizedTiled),
        ("quant-tiled", Repr::QuantizedTiled),
    ] {
        assert_eq!(Repr::parse(s).unwrap(), repr);
    }
    assert_eq!(Repr::parse(Repr::Quantized.name()).unwrap(), Repr::Quantized);
    assert_eq!(Repr::parse(Repr::QuantizedTiled.name()).unwrap(), Repr::QuantizedTiled);
}

#[test]
fn quantized_layers_slice_and_describe_like_the_f32_pair() {
    let (n, d) = (24usize, 32usize);
    let bundle = LayerBundle::synth(n, d, 0.85, 0.3, 5);
    let mut rng = Rng::new(5 ^ 0xc0de);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    for (layer, name) in [
        (&bundle.quantized as &dyn LinearKernel, "quantized"),
        (&bundle.quantized_tiled as &dyn LinearKernel, "quantized-tiled"),
    ] {
        assert_eq!(layer.name(), name);
        assert_eq!(layer.in_width(), d);
        assert_eq!(layer.out_width(), bundle.condensed.out_width());
        assert_eq!(layer.active_rows(), bundle.condensed.active_rows());
        assert_eq!(layer.row_weights(n), bundle.condensed.row_weights(n));
        assert!(
            layer.storage_bytes() < bundle.condensed.storage_bytes(),
            "{name}: int8 must store fewer bytes than the f32 condensed form"
        );
        // slicing partitions the output bit-for-bit: a shard cut through
        // the original row space concatenates to the unsharded forward
        let mut full = vec![0f32; layer.out_width()];
        layer.forward(&x, 1, &mut full, 1);
        let (lo, hi) = (layer.slice_rows(0, n / 2), layer.slice_rows(n / 2, n));
        assert_eq!(lo.out_width() + hi.out_width(), layer.out_width(), "{name}");
        let mut got = vec![0f32; lo.out_width()];
        lo.forward(&x, 1, &mut got, 1);
        let mut hi_out = vec![0f32; hi.out_width()];
        hi.forward(&x, 1, &mut hi_out, 1);
        got.extend_from_slice(&hi_out);
        assert_eq!(bits(&got), bits(&full), "{name}: slices must partition exactly");
    }
}
