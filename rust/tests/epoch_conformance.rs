//! Epoch conformance suite: under a live model swap, **every** forward is
//! bit-for-bit identical to exactly one epoch's stack — the one its
//! scratch is pinned to — and never a blend of two. Pinned across all
//! three swappable strategies ([`ReplicatedEngine`] via the
//! [`SwappableEngine`] umbrella, [`ScopedShardedEngine`], and the
//! persistent shard team), under both a deterministic swap script and a
//! concurrent flood with swaps landing mid-traffic.
//!
//! The mechanism under test (see `rust/src/inference/engine.rs`): each
//! workspace carries the `Arc` of the stack it was built for and forwards
//! compute with the *scratch's* stack, so atomicity per forward holds by
//! construction; [`Engine::ensure_current`] is the only place a worker
//! opts in to a newer epoch, and it reports the epoch the next forward
//! will compute under. If any engine ever read the published stack
//! mid-forward, the bit-exact oracle comparison here would catch the mix.

use std::sync::Arc;

use srigl::inference::model::{Activation, LayerSpec, Repr, SparseModel};
use srigl::inference::{
    Engine, EngineBuilder, ModelEpoch, ScopedShardedEngine, SwappableEngine,
};
use srigl::util::rng::Rng;

const D_IN: usize = 64;

fn stack(seed: u64) -> SparseModel {
    let widths = [48usize, 32, 16];
    let specs: Vec<LayerSpec> = widths
        .iter()
        .enumerate()
        .map(|(i, &n)| LayerSpec {
            n,
            repr: Repr::Condensed,
            sparsity: 0.9,
            ablated_frac: 0.25,
            activation: if i + 1 == widths.len() { Activation::Identity } else { Activation::Relu },
        })
        .collect();
    SparseModel::synth(D_IN, &specs, seed).unwrap()
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: idx {i}: {g} vs {w} (must be bit-for-bit)");
    }
}

/// Epoch seeds: index == epoch id. Different seeds make the stacks'
/// outputs differ, so a cross-epoch mix cannot masquerade as a match.
const EPOCH_SEEDS: [u64; 4] = [11, 23, 37, 51];

/// The three swappable strategies behind one umbrella type. Scoped is
/// constructed directly (the builder picks persistent for `shards > 1`).
fn engines(epoch0: &Arc<SparseModel>) -> Vec<(&'static str, SwappableEngine)> {
    vec![
        ("replicated", EngineBuilder::new().build_swappable(Arc::clone(epoch0)).unwrap()),
        (
            "scoped",
            SwappableEngine::Scoped(ScopedShardedEngine::from_model(epoch0, 2).unwrap()),
        ),
        ("persistent", EngineBuilder::new().shards(2).build_swappable(Arc::clone(epoch0)).unwrap()),
    ]
}

/// Deterministic swap script: a stale scratch keeps serving its pinned
/// epoch bit-for-bit after the swap publishes; `ensure_current` is the
/// only transition point, and afterwards the same scratch serves the new
/// epoch bit-for-bit. Exercised across batch sizes including the tiled
/// full-tile path (64) and a remainder (7).
#[test]
fn stale_scratch_serves_old_epoch_until_ensure_current() {
    let models: Vec<Arc<SparseModel>> =
        EPOCH_SEEDS.iter().map(|&s| Arc::new(stack(s))).collect();
    for &batch in &[1usize, 7, 64] {
        let mut rng = Rng::new(0xEC ^ batch as u64);
        let x: Vec<f32> = (0..batch * D_IN).map(|_| rng.normal_f32()).collect();
        // Fresh engines per batch size: each walks the whole epoch chain.
        for (name, engine) in engines(&models[0]) {
            let mut stale = engine.scratch(batch);
            for (id, model) in models.iter().enumerate().skip(1) {
                let prev = engine.epoch();
                assert_eq!(
                    engine.swap(ModelEpoch::new(id as u64, Arc::clone(model))).unwrap(),
                    id as u64,
                    "{name}: swap returns the published id"
                );
                // The stale scratch is still pinned to the previous epoch.
                assert_eq!(stale.epoch(), prev, "{name} b{batch}: scratch pins its epoch");
                let got_old = engine.forward(&x, batch, &mut stale, 1).to_vec();
                assert_bits_eq(
                    &got_old,
                    &models[prev as usize].forward_vec(&x, batch, 1),
                    &format!("{name} b{batch}: stale scratch == epoch {prev} oracle"),
                );
                // ensure_current is the one transition point.
                assert_eq!(engine.ensure_current(&mut stale, batch), id as u64);
                assert_eq!(stale.epoch(), id as u64);
                let got_new = engine.forward(&x, batch, &mut stale, 1).to_vec();
                assert_bits_eq(
                    &got_new,
                    &models[id].forward_vec(&x, batch, 1),
                    &format!("{name} b{batch}: rebuilt scratch == epoch {id} oracle"),
                );
            }
        }
    }
}

/// The conformance bar from the reload design: swaps land **mid-flood**
/// from a dedicated thread while reader threads hammer forwards, and every
/// single response is bit-for-bit one epoch's oracle — the epoch
/// `ensure_current` reported for that scratch — never a mix, even while
/// the persistent team re-plans shards under traffic.
#[test]
fn concurrent_swaps_never_mix_epochs() {
    let models: Vec<Arc<SparseModel>> =
        EPOCH_SEEDS.iter().map(|&s| Arc::new(stack(s))).collect();
    // Precompute each epoch's oracle per (batch, input) so reader threads
    // compare without recomputing references under the clock.
    let batches = [1usize, 3];
    let mut oracles: Vec<Vec<Vec<f32>>> = Vec::new(); // [epoch][batch_idx]
    let inputs: Vec<Vec<f32>> = batches
        .iter()
        .map(|&b| {
            let mut rng = Rng::new(0xF10D ^ b as u64);
            (0..b * D_IN).map(|_| rng.normal_f32()).collect()
        })
        .collect();
    for m in &models {
        oracles.push(
            batches.iter().zip(&inputs).map(|(&b, x)| m.forward_vec(x, b, 1)).collect(),
        );
    }

    for (name, engine) in engines(&models[0]) {
        let engine = Arc::new(engine);
        std::thread::scope(|s| {
            // Swapper: publish epochs 1..=3 spaced out so readers run
            // before, during, and after each publication.
            {
                let engine = Arc::clone(&engine);
                let models = &models;
                s.spawn(move || {
                    for (id, m) in models.iter().enumerate().skip(1) {
                        std::thread::sleep(std::time::Duration::from_millis(15));
                        engine
                            .swap(ModelEpoch::new(id as u64, Arc::clone(m)))
                            .expect("mid-flood swap");
                    }
                });
            }
            for t in 0..4usize {
                let engine = Arc::clone(&engine);
                let oracles = &oracles;
                let inputs = &inputs;
                s.spawn(move || {
                    let cap = *batches.iter().max().unwrap();
                    let mut scratch = engine.scratch(cap);
                    for i in 0..400usize {
                        let bi = (i + t) % batches.len();
                        let batch = batches[bi];
                        // Batch boundary: opt in to whatever epoch is
                        // current; the return pins what the next forward
                        // must compute under even if a swap lands now.
                        let pinned = engine.ensure_current(&mut scratch, cap);
                        assert_eq!(pinned, scratch.epoch(), "{name}: pin == scratch epoch");
                        let got =
                            engine.forward(&inputs[bi], batch, &mut scratch, 1).to_vec();
                        assert_bits_eq(
                            &got,
                            &oracles[pinned as usize][bi],
                            &format!("{name} reader {t} iter {i}: epoch {pinned} b{batch}"),
                        );
                    }
                });
            }
        });
        // Flood is over: everyone converges on the final epoch.
        assert_eq!(engine.epoch(), (models.len() - 1) as u64, "{name}: final epoch");
        let mut s = engine.scratch(1);
        assert_eq!(engine.ensure_current(&mut s, 1), 3);
        let got = engine.forward(&inputs[0], 1, &mut s, 1).to_vec();
        assert_bits_eq(&got, &oracles[3][0], &format!("{name}: settled on epoch 3"));
    }
}

/// Failed swaps (stale id, input-width change, un-shardable stack) must
/// leave the published epoch — and its bit-exact outputs — untouched.
#[test]
fn failed_swaps_leave_the_published_epoch_serving() {
    let m0 = Arc::new(stack(EPOCH_SEEDS[0]));
    let m1 = Arc::new(stack(EPOCH_SEEDS[1]));
    let mut rng = Rng::new(0xBAD);
    let x: Vec<f32> = (0..2 * D_IN).map(|_| rng.normal_f32()).collect();
    let narrow_in = Arc::new(
        SparseModel::synth(
            32,
            &[LayerSpec {
                n: 16,
                repr: Repr::Condensed,
                sparsity: 0.9,
                ablated_frac: 0.0,
                activation: Activation::Identity,
            }],
            5,
        )
        .unwrap(),
    );
    let one_neuron = Arc::new(
        SparseModel::synth(
            D_IN,
            &[LayerSpec {
                n: 1,
                repr: Repr::Condensed,
                sparsity: 0.5,
                ablated_frac: 0.0,
                activation: Activation::Identity,
            }],
            5,
        )
        .unwrap(),
    );
    for (name, engine) in engines(&m0) {
        assert_eq!(engine.swap(ModelEpoch::new(1, Arc::clone(&m1))).unwrap(), 1);
        // Stale and duplicate ids refuse without publishing.
        assert!(engine.swap(ModelEpoch::new(1, Arc::clone(&m0))).is_err(), "{name}: dup id");
        assert!(engine.swap(ModelEpoch::new(0, Arc::clone(&m0))).is_err(), "{name}: stale id");
        // Input-width changes refuse (connections validated shape once).
        assert!(engine.swap(ModelEpoch::new(2, Arc::clone(&narrow_in))).is_err(), "{name}");
        // Sharded strategies also refuse stacks too narrow to re-plan.
        if name != "replicated" {
            assert!(
                engine.swap(ModelEpoch::new(2, Arc::clone(&one_neuron))).is_err(),
                "{name}: un-shardable stack must not publish"
            );
        }
        assert_eq!(engine.epoch(), 1, "{name}: failed swaps leave epoch 1");
        let mut s = engine.scratch(2);
        let got = engine.forward(&x, 2, &mut s, 1).to_vec();
        assert_bits_eq(&got, &m1.forward_vec(&x, 2, 1), &format!("{name}: epoch 1 still serves"));
    }
}
