//! Exhaustive model checking of the serving concurrency core.
//!
//! Built only under `RUSTFLAGS="--cfg loom"` (otherwise this file is an
//! empty crate): the `crate::util::sync` shim then swaps every primitive
//! used by the modeled types for the vendored loom checker's
//! decision-point instrumented versions, and `loom::model` explores all
//! interleavings (bounded at `LOOM_MAX_PREEMPTIONS`, default 2 — the
//! CHESS result: almost all concurrency bugs surface within 2
//! preemptions).
//!
//! Four primitives are modeled — see docs/ANALYSIS.md for the invariant
//! catalogue and the checker's honest limitations (sequentially
//! consistent memory model; TSan covers real orderings):
//!
//! * [`Injector`] — no lost wakeups; bounded push accounts for every item
//!   exactly once.
//! * [`Egress`] — overflow accounting conserves frames; close vs drain
//!   never loses an in-flight response.
//! * [`EpochCell`] — the lock-free shadow id never *leads* the published
//!   pair, and snapshots are internally consistent.
//! * shard [`Mailbox`] + [`DoneLatch`] — the post → run → latch handoff
//!   never dereferences a reclaimed job (use-after-free probe), and
//!   `wait_and_reset` is correct across rounds and with parallel
//!   arrivals.
//!
//! Every model is mirrored by a std-threaded stress test in
//! `rust/tests/concurrency_stress.rs` (same scenario, real parallelism).
#![cfg(loom)]

use loom::thread;

use srigl::inference::engine::{DoneLatch, EpochCell, Mailbox};
use srigl::inference::frontend::{Egress, SendOutcome};
use srigl::net::{ResponseBody, ResponseFrame};
use srigl::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use srigl::util::sync::Arc;
use srigl::util::threadpool::Injector;

fn out_frame(id: u64) -> ResponseFrame {
    ResponseFrame { id, body: ResponseBody::Output { rows: 1, data: vec![1.0] } }
}

// ---------------------------------------------------------------------------
// Injector
// ---------------------------------------------------------------------------

/// A consumer parked in `pop_batch` must see every pushed item and the
/// close — under every interleaving of push/close with the blocking pop.
/// A lost wakeup (push landing between the consumer's emptiness check and
/// its park) would show up as a loom-reported deadlock.
#[test]
fn injector_handoff_no_lost_wakeup() {
    loom::model(|| {
        let inj: Arc<Injector<u32>> = Arc::new(Injector::new());
        let producer = {
            let inj = Arc::clone(&inj);
            thread::spawn(move || {
                inj.push(1);
                inj.push(2);
                inj.close();
            })
        };
        let mut got = Vec::new();
        loop {
            if inj.pop_batch(2, &mut got) == 0 {
                break;
            }
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1, 2], "FIFO, nothing lost, nothing duplicated");
    });
}

/// With a bound of 1, every `push_bounded` is either accepted or rejected
/// — never both, never neither — and the consumer drains exactly the
/// accepted items. This is the conservation law the front-end's
/// `rejected` counter relies on.
#[test]
fn injector_bounded_counts_every_item_once() {
    loom::model(|| {
        let inj: Arc<Injector<u32>> = Arc::new(Injector::with_capacity(1));
        let producer = {
            let inj = Arc::clone(&inj);
            thread::spawn(move || {
                let mut accepted = 0usize;
                for item in [10u32, 20] {
                    if inj.push_bounded(item).is_ok() {
                        accepted += 1;
                    }
                }
                inj.close();
                accepted
            })
        };
        let mut buf = Vec::new();
        let mut consumed = 0usize;
        loop {
            buf.clear();
            let n = inj.pop_batch(2, &mut buf);
            if n == 0 {
                break;
            }
            consumed += n;
        }
        let accepted = producer.join().unwrap();
        assert!(accepted >= 1, "an empty bounded queue must accept the first push");
        assert_eq!(consumed, accepted, "exactly the accepted items are consumed");
    });
}

// ---------------------------------------------------------------------------
// Egress
// ---------------------------------------------------------------------------

/// Overflow accounting conserves frames under a concurrently draining
/// writer: with capacity 1 and headroom 1, three racing sends split into
/// Queued / ConvertedBusy / Dropped in schedule-dependent proportions,
/// but in EVERY schedule the writer receives exactly the Queued +
/// ConvertedBusy frames (a ConvertedBusy enqueues a Busy hint) and the
/// Dropped ones vanish without blocking anybody.
#[test]
fn egress_overflow_headroom_counting() {
    loom::model(|| {
        let e = Arc::new(Egress::with_headroom(1, 1, 7));
        let producer = {
            let e = Arc::clone(&e);
            thread::spawn(move || {
                let (mut queued, mut busy, mut dropped) = (0usize, 0usize, 0usize);
                for id in 1..=3u64 {
                    e.job_started();
                    match e.send(out_frame(id)) {
                        SendOutcome::Queued => queued += 1,
                        SendOutcome::ConvertedBusy => busy += 1,
                        SendOutcome::Dropped => dropped += 1,
                        SendOutcome::Gone => panic!("queue closed while jobs in flight"),
                    }
                    e.job_finished();
                }
                e.reader_done();
                (queued, busy, dropped)
            })
        };
        let mut received = 0usize;
        while e.recv().is_some() {
            received += 1;
        }
        let (queued, busy, dropped) = producer.join().unwrap();
        assert_eq!(queued + busy + dropped, 3, "every send has exactly one outcome");
        assert_eq!(received, queued + busy, "writer drains exactly the enqueued frames");
    });
}

/// The close-vs-drain race: a response in flight (job_started has run)
/// must never be lost to a concurrent reader_done — the inflight count
/// keeps the queue open until job_finished, and the writer's blocking
/// recv both drains the frame and terminates. Termination failure (a
/// lost close notification) would surface as a loom deadlock.
#[test]
fn egress_close_vs_drain_race() {
    loom::model(|| {
        let e = Arc::new(Egress::with_headroom(4, 1, 7));
        // The reader accounts the job before handing it off — model that
        // happens-before edge by running job_started first.
        e.job_started();
        let worker = {
            let e = Arc::clone(&e);
            thread::spawn(move || {
                let outcome = e.send(out_frame(1));
                e.job_finished();
                outcome
            })
        };
        let reader = {
            let e = Arc::clone(&e);
            thread::spawn(move || e.reader_done())
        };
        let mut got = 0usize;
        while e.recv().is_some() {
            got += 1;
        }
        assert_eq!(worker.join().unwrap(), SendOutcome::Queued, "open while inflight > 0");
        reader.join().unwrap();
        assert_eq!(got, 1, "the in-flight response is never lost to the close");
    });
}

// ---------------------------------------------------------------------------
// EpochCell
// ---------------------------------------------------------------------------

/// Epoch-shadow coherence: a reader that peeks the lock-free shadow id
/// and then takes a locked snapshot must never see a snapshot OLDER than
/// its peek (the shadow may trail the lock, never lead it), and every
/// snapshot pairs the id with that id's stack (no torn publish).
#[test]
fn epoch_shadow_never_leads_published() {
    loom::model(|| {
        let cell = Arc::new(EpochCell::new(0, Arc::new(0u64)));
        let writer = {
            let cell = Arc::clone(&cell);
            thread::spawn(move || {
                cell.publish(1, Arc::new(1u64)).unwrap();
                cell.publish(2, Arc::new(2u64)).unwrap();
            })
        };
        let shadow = cell.epoch();
        let (id, v) = cell.current();
        assert!(id >= shadow, "snapshot id {id} older than the peeked shadow {shadow}");
        assert_eq!(*v, id, "snapshot pairs the id with that id's stack");
        writer.join().unwrap();
        assert_eq!(cell.epoch(), 2);
        assert_eq!(*cell.current().1, 2);
    });
}

// ---------------------------------------------------------------------------
// Shard mailbox + completion latch
// ---------------------------------------------------------------------------

/// A probe job mimicking [`srigl::inference::engine`]'s `ForwardJob`: a
/// raw pointer into the coordinator's stack frame plus a liveness flag
/// the coordinator clears after reclaiming the storage. A shard
/// dereferencing after the latch released the coordinator would trip the
/// `valid` assertion — the use-after-free detector.
enum ProbeJob {
    Run { data: *const u64, valid: Arc<AtomicBool> },
    Stop,
}

// SAFETY: `data` is only dereferenced while the posting coordinator
// blocks on the completion latch, which keeps the pointed-to stack slot
// alive (the property this model exists to verify — the `valid` flag
// turns a violation into a deterministic assertion rather than UB).
unsafe impl Send for ProbeJob {}

/// Coordinator + one shard, two rounds then Stop: verifies the handoff
/// never loses a job or a wakeup, that `wait_and_reset` actually resets
/// (round 2 would hang or misfire otherwise), and that the shard never
/// touches a job after the coordinator reclaimed it.
#[test]
fn mailbox_latch_rounds_reset_correctly() {
    loom::model(|| {
        let mb: Arc<Mailbox<ProbeJob>> = Arc::new(Mailbox::new());
        let latch = Arc::new(DoneLatch::new());
        let sum = Arc::new(AtomicU64::new(0));
        let shard = {
            let (mb, latch, sum) = (Arc::clone(&mb), Arc::clone(&latch), Arc::clone(&sum));
            thread::spawn(move || loop {
                match mb.take() {
                    ProbeJob::Stop => return,
                    ProbeJob::Run { data, valid } => {
                        assert!(
                            valid.load(Ordering::SeqCst),
                            "use-after-free: shard dereferenced a reclaimed job"
                        );
                        // SAFETY: the coordinator blocks on the latch until
                        // `arrive` below, keeping `data`'s stack slot alive;
                        // the `valid` assertion above would catch a latch
                        // bug as a test failure before UB.
                        sum.fetch_add(unsafe { *data }, Ordering::SeqCst);
                        latch.arrive();
                    }
                }
            })
        };
        for round in 1..=2u64 {
            let x: u64 = round; // stack storage the job points into
            let valid = Arc::new(AtomicBool::new(true));
            mb.put(ProbeJob::Run { data: &x, valid: Arc::clone(&valid) });
            latch.wait_and_reset(1);
            valid.store(false, Ordering::SeqCst); // x is dead to the shard now
        }
        mb.put(ProbeJob::Stop);
        shard.join().unwrap();
        assert_eq!(sum.load(Ordering::SeqCst), 3, "both rounds ran exactly once");
    });
}

/// Coordinator + two shards, one round then Stop: parallel arrivals at
/// the latch (the real team's shape). The coordinator must not wake
/// until BOTH shards arrived, whatever order they run in.
#[test]
fn mailbox_latch_parallel_arrivals() {
    loom::model(|| {
        let mbs: Vec<Arc<Mailbox<ProbeJob>>> =
            (0..2).map(|_| Arc::new(Mailbox::new())).collect();
        let latch = Arc::new(DoneLatch::new());
        let sum = Arc::new(AtomicU64::new(0));
        let shards: Vec<_> = mbs
            .iter()
            .map(|mb| {
                let (mb, latch, sum) = (Arc::clone(mb), Arc::clone(&latch), Arc::clone(&sum));
                thread::spawn(move || loop {
                    match mb.take() {
                        ProbeJob::Stop => return,
                        ProbeJob::Run { data, valid } => {
                            assert!(valid.load(Ordering::SeqCst), "use-after-free");
                            // SAFETY: same latch argument as the two-round
                            // model above — the coordinator's blocking wait
                            // outlives this dereference.
                            sum.fetch_add(unsafe { *data }, Ordering::SeqCst);
                            latch.arrive();
                        }
                    }
                })
            })
            .collect();
        let x: u64 = 5; // shared job input on the coordinator's stack
        let valid = Arc::new(AtomicBool::new(true));
        for mb in &mbs {
            mb.put(ProbeJob::Run { data: &x, valid: Arc::clone(&valid) });
        }
        latch.wait_and_reset(2);
        valid.store(false, Ordering::SeqCst);
        assert_eq!(sum.load(Ordering::SeqCst), 10, "both shards ran the job exactly once");
        for mb in &mbs {
            mb.put(ProbeJob::Stop);
        }
        for s in shards {
            s.join().unwrap();
        }
    });
}
