//! Integration tests over the real PJRT runtime + AOT artifacts: the full
//! L3 <- L2 <- L1 stack on the tiny MLP. Requires `make artifacts`; each
//! test skips (with a message) when artifacts are absent so `cargo test`
//! stays runnable on a fresh clone.

use srigl::runtime::Manifest;
use srigl::sparsity::Distribution;
use srigl::train::{LrSchedule, Method, Session, TrainConfig};

fn session() -> Option<Session> {
    if Manifest::default_dir().join("manifest.json").exists() {
        Some(Session::open().expect("session"))
    } else {
        eprintln!("skipping integration test: run `make artifacts`");
        None
    }
}

fn cfg(method: Method, sparsity: f64, steps: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        model: "mlp_tiny".into(),
        method,
        sparsity,
        distribution: Distribution::Erk,
        total_steps: steps,
        delta_t: 10,
        alpha: 0.3,
        lr: LrSchedule::step_decay(0.1, &[steps / 2], 0.2),
        grad_accum: 1,
        seed,
        eval_batches: 8,
        dense_first_layer: false,
    }
}

#[test]
fn srigl_trains_and_keeps_invariants() {
    let Some(sess) = session() else { return };
    let mut tr = sess
        .trainer(cfg(Method::SRigL { ablation: true, gamma_sal: 0.3 }, 0.9, 120, 0))
        .unwrap();
    let rep = tr.run().unwrap();

    // learning happened
    let first = rep.losses[0];
    let last = *rep.losses.last().unwrap();
    assert!(last < first * 0.8, "loss did not descend: {first} -> {last}");
    assert!(rep.eval_metric > 0.4, "accuracy {:.3} <= chance-ish (4 classes)", rep.eval_metric);

    // sparsity close to target, constant fan-in everywhere
    assert!((rep.final_sparsity - 0.9).abs() < 0.03, "sparsity {}", rep.final_sparsity);
    for (li, mask) in tr.masks.iter().enumerate() {
        assert!(mask.is_constant_fan_in(tr.ks[li]), "layer {li} fan-in broken");
    }

    // pruned weights are exactly zero in the trained params
    for (li, &pi) in tr.sparse_idx.iter().enumerate() {
        for (w, m) in tr.params[pi].data.iter().zip(&tr.masks[li].t.data) {
            if *m == 0.0 {
                assert_eq!(*w, 0.0, "layer {li}: pruned weight moved");
            }
        }
    }
}

#[test]
fn rigl_vs_static_topology_evolves() {
    let Some(sess) = session() else { return };
    let mut rigl = sess.trainer(cfg(Method::RigL, 0.9, 80, 1)).unwrap();
    let rep = rigl.run().unwrap();
    assert!(!rep.updates.is_empty(), "no topology updates ran");
    let total_pruned: usize =
        rep.updates.iter().flat_map(|u| u.per_layer.iter().map(|s| s.pruned)).sum();
    assert!(total_pruned > 0, "RigL never rewired");
    assert!(rep.itop_rate > 1.0 - 0.9 + 1e-6, "ITOP should exceed initial density");

    let mut st = sess.trainer(cfg(Method::Static { structured: true }, 0.9, 80, 1)).unwrap();
    let rep_s = st.run().unwrap();
    assert!((rep_s.itop_rate - 0.1).abs() < 0.02, "static ITOP stays at density");
}

#[test]
fn dense_grad_signal_exists_at_pruned_positions() {
    let Some(sess) = session() else { return };
    let mut tr = sess
        .trainer(cfg(Method::SRigL { ablation: false, gamma_sal: 0.0 }, 0.95, 5, 2))
        .unwrap();
    for s in 0..3 {
        tr.step(s).unwrap();
    }
    let grads = tr.dense_grads().unwrap();
    for (li, g) in grads.iter().enumerate() {
        let mask = &tr.masks[li];
        let pruned_nonzero = g
            .data
            .iter()
            .zip(&mask.t.data)
            .filter(|(g, m)| **m == 0.0 && **g != 0.0)
            .count();
        assert!(pruned_nonzero > 0, "layer {li}: no grow signal at pruned weights");
    }
}

#[test]
fn condensed_export_matches_trained_params() {
    let Some(sess) = session() else { return };
    let mut tr = sess
        .trainer(cfg(Method::SRigL { ablation: true, gamma_sal: 0.3 }, 0.9, 60, 3))
        .unwrap();
    tr.run().unwrap();
    for li in 0..tr.sparse_idx.len() {
        let c = tr.export_condensed(li).expect("SRigL maintains constant fan-in");
        let pi = tr.sparse_idx[li];
        let dense = c.to_dense();
        assert_eq!(dense.data, tr.params[pi].data, "layer {li} condensed mismatch");
    }
}

#[test]
fn seeds_reproduce_exactly() {
    let Some(sess) = session() else { return };
    let run = |seed| {
        let mut t = sess
            .trainer(cfg(Method::SRigL { ablation: true, gamma_sal: 0.3 }, 0.9, 40, seed))
            .unwrap();
        let r = t.run().unwrap();
        (r.losses.clone(), r.eval_metric)
    };
    let (l1, e1) = run(7);
    let (l2, e2) = run(7);
    assert_eq!(l1, l2, "same seed must reproduce the loss trace");
    assert_eq!(e1, e2);
    let (l3, _) = run(8);
    assert_ne!(l1, l3, "different seeds should differ");
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let Some(sess) = session() else { return };
    let c = cfg(Method::SRigL { ablation: true, gamma_sal: 0.3 }, 0.9, 30, 5);
    let mut tr = sess.trainer(c.clone()).unwrap();
    for s in 0..30 {
        tr.step(s).unwrap();
    }
    let dir = std::env::temp_dir().join(format!("srigl_it_ckpt_{}", std::process::id()));
    tr.checkpoint(30).save(&dir).unwrap();

    // fresh trainer restored from disk must produce the identical params
    let mut tr2 = sess.trainer(c).unwrap();
    let ck = srigl::train::Checkpoint::load(&dir).unwrap();
    assert_eq!(ck.step, 30);
    tr2.restore(ck).unwrap();
    for (a, b) in tr.params.iter().zip(&tr2.params) {
        assert_eq!(a.data, b.data);
    }
    for (a, b) in tr.masks.iter().zip(&tr2.masks) {
        assert_eq!(a.t.data, b.t.data);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn srste_trains_and_projects_nm() {
    let Some(sess) = session() else { return };
    let cfg = srigl::train::SrSteConfig {
        model: "mlp_tiny".into(),
        n: 1,
        m: 4,
        steps: 60,
        lr: 0.05,
        lambda_w: 2e-4,
        momentum: 0.9,
        seed: 0,
        eval_batches: 8,
    };
    let rep = srigl::train::train_srste(&sess, &cfg).unwrap();
    // 1:4 pattern = 75% sparse at eval time
    assert!((rep.final_sparsity - 0.75).abs() < 1e-6, "sparsity {}", rep.final_sparsity);
    let first = rep.losses[0];
    let last = *rep.losses.last().unwrap();
    assert!(last < first, "SR-STE loss did not descend: {first} -> {last}");
    assert!(rep.eval_metric > 0.3, "accuracy {:.3}", rep.eval_metric);
}

#[test]
fn methods_hit_target_sparsity() {
    let Some(sess) = session() else { return };
    for method in [
        Method::Static { structured: false },
        Method::Set,
        Method::RigL,
        Method::SRigL { ablation: true, gamma_sal: 0.3 },
    ] {
        let mut tr = sess.trainer(cfg(method, 0.8, 30, 4)).unwrap();
        let rep = tr.run().unwrap();
        assert!(
            (rep.final_sparsity - 0.8).abs() < 0.05,
            "{}: sparsity {:.3}",
            method.label(),
            rep.final_sparsity
        );
    }
}
