//! Arena integration tests: trace distribution shape, end-to-end duel
//! determinism, and trajectory persistence.
//!
//! The distribution bounds (CV windows, diurnal ratio, heavy-tail mass)
//! are pre-verified against an exact Python port of the generator
//! (`python/tests/test_arena_traces.py`) at the same seeds and
//! parameters; margins are wide enough that libm ULP differences cannot
//! flip them. Wire-mode (loopback TCP) coverage lives in
//! `rust/tests/serve_socket.rs` (`socket_arena_wire_duel`), which CI runs
//! serialized with the other socket tests.

use std::sync::Arc;

use srigl::arena::{
    self, parse_engine_spec, run_duel, DuelConfig, Scenario, Trace, TraceSpec,
};
use srigl::inference::{Activation, EngineBuilder, LayerSpec, Repr, SparseModel};
use srigl::util::json::Json;

// The exact parameters the Python oracle verified (see module docs).
const SHAPE_N: usize = 2000;
const SHAPE_GAP_US: f64 = 100.0;
const SHAPE_MAX_ROWS: usize = 8;
const SHAPE_POOL: usize = 32;
const SHAPE_SEEDS: [u64; 3] = [1, 2, 3];

fn shape_trace(scenario: Scenario, seed: u64) -> Trace {
    Trace::generate(&TraceSpec {
        scenario,
        n_requests: SHAPE_N,
        mean_gap_us: SHAPE_GAP_US,
        max_rows: SHAPE_MAX_ROWS,
        pool: SHAPE_POOL,
        seed,
    })
}

/// Coefficient of variation (std/mean, unbiased variance).
fn cv(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    var.sqrt() / mean
}

#[test]
fn poisson_gaps_have_unit_cv() {
    // exponential inter-arrivals: CV = 1 (the clamp and rounding shave a
    // little); a generator bug (e.g. uniform gaps, CV ~ 0.58) lands far
    // outside the window
    for seed in SHAPE_SEEDS {
        let c = cv(&shape_trace(Scenario::Poisson, seed).gaps_us());
        assert!((0.8..1.25).contains(&c), "seed {seed}: poisson CV {c}");
    }
}

#[test]
fn bursty_gaps_are_overdispersed() {
    // flash-crowd mixture: ~75% of events inside 50x-faster bursts pushes
    // the gap CV to ~2.4-2.5 (Python oracle) — far above any Poisson
    // stream
    for seed in SHAPE_SEEDS {
        let c = cv(&shape_trace(Scenario::Bursty, seed).gaps_us());
        assert!(c > 1.8, "seed {seed}: bursty CV {c} not overdispersed");
    }
}

#[test]
fn diurnal_middle_third_runs_hotter() {
    // half-sine rate: mid-trace rate ~3-4x the edges, so mid-trace gaps
    // are well under 70% of the outer thirds' (oracle: 55-58%)
    for seed in SHAPE_SEEDS {
        let gaps = shape_trace(Scenario::Diurnal, seed).gaps_us();
        let third = gaps.len() / 3;
        let mean = |s: &[f64]| s.iter().sum::<f64>() / s.len() as f64;
        let outer =
            (mean(&gaps[..third]) + mean(&gaps[gaps.len() - third..])) / 2.0;
        let middle = mean(&gaps[third..2 * third]);
        assert!(
            middle < 0.7 * outer,
            "seed {seed}: middle gap {middle:.1} vs outer {outer:.1}"
        );
    }
}

#[test]
fn heavytail_rows_are_mostly_one_with_monsters() {
    // Pareto(1.2): P(rows == 1) = 1 - 2^-1.2 ~ 0.565, and the cap is hit
    // (oracle: frac 0.55-0.59, max always 8)
    for seed in SHAPE_SEEDS {
        let t = shape_trace(Scenario::HeavyTail, seed);
        let ones =
            t.events.iter().filter(|e| e.rows == 1).count() as f64 / t.events.len() as f64;
        assert!((0.45..0.75).contains(&ones), "seed {seed}: frac(rows=1) {ones}");
        assert_eq!(t.max_event_rows(), SHAPE_MAX_ROWS, "seed {seed}: cap never hit");
    }
}

fn duel_model() -> Arc<SparseModel> {
    let spec = |n, act| LayerSpec {
        n,
        repr: Repr::Condensed,
        sparsity: 0.8,
        ablated_frac: 0.2,
        activation: act,
    };
    Arc::new(
        SparseModel::synth(48, &[spec(32, Activation::Relu), spec(16, Activation::Identity)], 7)
            .unwrap(),
    )
}

fn duel_trace() -> Trace {
    Trace::generate(&TraceSpec {
        scenario: Scenario::Bursty,
        n_requests: 150,
        mean_gap_us: 20.0,
        max_rows: 4,
        pool: 16,
        seed: 5,
    })
}

#[test]
fn duel_serves_everything_and_fingerprint_is_deterministic() {
    let model = duel_model();
    let trace = duel_trace();
    let a = parse_engine_spec("workers=2,batch=8").unwrap();
    let b = parse_engine_spec("workers=2,adaptive=8").unwrap();
    let cfg = DuelConfig { rounds: 2, wire: false, clients: 1, max_retries: 0 };
    let run = || {
        run_duel(&model, ("a", &a), ("b", &b), &trace, &cfg, |_| {}).unwrap()
    };
    let s1 = run();
    let s2 = run();

    // in-process replay answers every request, every round
    for rps in s1.a_rps.iter().chain(&s1.b_rps) {
        assert!(*rps > 0.0);
    }
    assert_eq!(s1.paired, 2 * 150, "all positions answered on both sides");

    // the summary JSON parses, and input-determined keys agree across runs
    let j1 = Json::parse(&s1.to_json().to_string()).unwrap();
    let j2 = Json::parse(&s2.to_json().to_string()).unwrap();
    for key in ["scenario", "digest", "n_requests", "gap_us", "max_rows", "seed", "rounds"] {
        assert_eq!(
            j1.get(key).unwrap().to_string(),
            j2.get(key).unwrap().to_string(),
            "fingerprint key {key} must not depend on wall-clock"
        );
    }
    assert_eq!(
        j1.get("digest").unwrap().as_str().unwrap(),
        format!("{:016x}", trace.digest())
    );
    assert!(!s1.headline().is_empty());
}

#[test]
fn identical_configs_duel_close_to_even() {
    // Same spec on both sides replaying the same paced trace: both sides'
    // wall-clock is pinned to the trace span, so the mean throughput
    // delta must be a small fraction of the throughput itself. (The CI
    // verdict on identical configs is *usually* inconclusive, but a 95%
    // interval excludes zero ~5% of the time by construction — asserting
    // on the verdict would be a flaky test, so assert the magnitude.)
    let model = duel_model();
    let trace = duel_trace();
    let e = parse_engine_spec("workers=2,batch=8").unwrap();
    let cfg = DuelConfig { rounds: 4, wire: false, clients: 1, max_retries: 0 };
    let s = run_duel(&model, ("same", &e), ("same", &e), &trace, &cfg, |_| {}).unwrap();
    let mean_rps = s.a_rps.iter().sum::<f64>() / s.a_rps.len() as f64;
    assert!(
        s.rps_delta.mean.abs() < 0.25 * mean_rps,
        "identical configs differ by {:.1} rps of {mean_rps:.1}",
        s.rps_delta.mean
    );
}

#[test]
fn oversized_rows_are_rejected_up_front() {
    let model = duel_model();
    let trace = Trace::generate(&TraceSpec {
        scenario: Scenario::HeavyTail,
        n_requests: 300,
        mean_gap_us: 0.0,
        max_rows: 8,
        pool: 4,
        seed: 2,
    });
    assert_eq!(trace.max_event_rows(), 8);
    let small = parse_engine_spec("workers=1,batch=4").unwrap();
    let big = parse_engine_spec("workers=1,batch=8").unwrap();
    let cfg = DuelConfig { rounds: 1, ..DuelConfig::default() };
    let err = run_duel(&model, ("small", &small), ("big", &big), &trace, &cfg, |_| {})
        .unwrap_err();
    assert!(format!("{err:#}").contains("cap is 4"), "{err:#}");
    // and the workable pair runs fine
    run_duel(&model, ("big", &big), ("big", &big), &trace, &cfg, |_| {}).unwrap();
}

#[test]
fn duel_record_persists_and_loads() {
    let dir = std::env::temp_dir()
        .join(format!("srigl-arena-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let model = duel_model();
    let trace = duel_trace();
    let e = parse_engine_spec("workers=1,batch=8").unwrap();
    let cfg = DuelConfig { rounds: 1, ..DuelConfig::default() };
    let s = run_duel(&model, ("x", &e), ("y", &e), &trace, &cfg, |_| {}).unwrap();
    arena::persist::persist_record_in(
        &dir,
        "arena",
        "arena-bursty",
        &s.headline(),
        s.to_json(),
        Some("it-test"),
    )
    .unwrap();

    let hist = arena::load_history(&dir).unwrap();
    assert_eq!(hist.len(), 1);
    assert_eq!(hist[0].name, "arena-bursty");
    assert_eq!(hist[0].label, "it-test");
    assert_eq!(
        hist[0].payload.get("digest").unwrap().as_str().unwrap(),
        format!("{:016x}", trace.digest())
    );
    assert!(arena::render_history(&hist).contains("arena-bursty"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn builder_caps_bound_trace_rows() {
    // EngineBuilder::max_batch is the contract validate() enforces
    let b = EngineBuilder::new().fixed_batch(4);
    assert_eq!(b.max_batch(), 4);
    let t = shape_trace(Scenario::Poisson, 1);
    assert!(srigl::arena::replay::validate(&t, &b).is_err(), "8-row trace vs cap 4");
}
