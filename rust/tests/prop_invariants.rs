//! Property-based tests over the coordinator invariants (in-tree driver
//! standing in for proptest — offline environment; see Cargo.toml).
//!
//! Each property runs across a randomized case grid seeded deterministically
//! so failures are reproducible: the failing (seed, case) prints in the
//! assertion message.

use srigl::dst::{LayerView, RigL, SRigL, Set, TopologyUpdater};
use srigl::sparsity::distribution::{
    achieved_sparsity, fan_in_targets, layer_densities, Distribution, LayerShape,
};
use srigl::sparsity::{Condensed, CondensedTiled, Csr, Mask};
use srigl::tensor::Tensor;
use srigl::util::json::Json;
use srigl::util::rng::Rng;

const CASES: u64 = 60;

struct Layer {
    w: Tensor,
    v: Tensor,
    mask: Mask,
    grad: Tensor,
    k: usize,
    budget: usize,
}

fn rand_layer(rng: &mut Rng, constant: bool) -> Layer {
    let n = 4 + rng.below(40);
    let f = 4 + rng.below(60);
    let k = 1 + rng.below(f.min(16));
    let mask = if constant {
        Mask::random_constant_fan_in(&[n, f], k, rng)
    } else {
        Mask::random_per_layer(&[n, f], n * k, rng)
    };
    let mut w = Tensor::normal(&[n, f], 1.0, rng);
    w.mul_assign(&mask.t);
    Layer { w, v: Tensor::zeros(&[n, f]), mask, grad: Tensor::normal(&[n, f], 1.0, rng), k, budget: n * k }
}

fn view(l: &mut Layer) -> LayerView<'_> {
    LayerView { w: &mut l.w, v: &mut l.v, mask: &mut l.mask, grad: &l.grad, k: &mut l.k, budget: l.budget }
}

fn consistent(l: &Layer, ctx: &str) {
    for (i, &m) in l.mask.t.data.iter().enumerate() {
        if m == 0.0 {
            assert_eq!(l.w.data[i], 0.0, "{ctx}: live weight at masked idx {i}");
            assert_eq!(l.v.data[i], 0.0, "{ctx}: live momentum at masked idx {i}");
        }
    }
}

#[test]
fn prop_srigl_constant_fan_in_invariant() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let mut l = rand_layer(&mut rng, true);
        let gamma = rng.uniform();
        let upd = SRigL { ablation: rng.uniform() < 0.7, gamma_sal: gamma };
        for step in 0..6 {
            let frac = rng.uniform() * 0.4;
            let stats = upd.update(&mut view(&mut l), frac, &mut rng);
            let ctx = format!("seed {seed} step {step} gamma {gamma:.2}");
            assert!(l.mask.is_constant_fan_in(stats.k), "{ctx}: fan-in broken");
            assert!(l.mask.nnz() <= l.budget, "{ctx}: budget exceeded");
            assert_eq!(l.mask.active_neurons(), stats.active_neurons, "{ctx}");
            assert!(stats.active_neurons >= 1, "{ctx}: layer collapsed");
            consistent(&l, &ctx);
        }
    }
}

#[test]
fn prop_srigl_ablation_monotone() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let mut l = rand_layer(&mut rng, true);
        let upd = SRigL { ablation: true, gamma_sal: 0.3 + rng.uniform() * 0.6 };
        let mut dead = std::collections::HashSet::new();
        for step in 0..6 {
            // fresh gradient each round (as the trainer provides)
            l.grad = Tensor::normal(&l.grad.shape.clone(), 1.0, &mut rng);
            upd.update(&mut view(&mut l), rng.uniform() * 0.4, &mut rng);
            let counts = l.mask.fan_in_counts();
            for (r, &c) in counts.iter().enumerate() {
                if dead.contains(&r) {
                    assert_eq!(c, 0, "seed {seed} step {step}: neuron {r} revived");
                }
                if c == 0 {
                    dead.insert(r);
                }
            }
        }
    }
}

#[test]
fn prop_rigl_set_preserve_nnz() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        for structured in [false, true] {
            let mut l = rand_layer(&mut rng, structured);
            let nnz = l.mask.nnz();
            let updater: Box<dyn TopologyUpdater> =
                if seed % 2 == 0 { Box::new(RigL) } else { Box::new(Set) };
            for step in 0..5 {
                let frac = rng.uniform() * 0.5;
                let stats = updater.update(&mut view(&mut l), frac, &mut rng);
                assert_eq!(l.mask.nnz(), nnz, "seed {seed} step {step}: nnz drift");
                assert_eq!(stats.pruned, stats.grown, "seed {seed}: prune != grow");
                consistent(&l, &format!("seed {seed} step {step}"));
            }
        }
    }
}

#[test]
fn prop_condensed_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let mut l = rand_layer(&mut rng, true);
        // randomly ablate some neurons to exercise the compact path
        let n = l.mask.neurons;
        let n_ablate = rng.below(n / 2 + 1);
        for r in rng.choose_k(n, n_ablate) {
            for j in 0..l.mask.fan_in {
                l.mask.set(r, j, false);
                l.w.data[r * l.mask.fan_in + j] = 0.0;
            }
        }
        let c = Condensed::from_masked(&l.w, &l.mask).unwrap();
        assert_eq!(c.to_dense().data, l.w.data, "seed {seed}: dense roundtrip");
        assert_eq!(c.to_mask().t.data, l.mask.t.data, "seed {seed}: mask roundtrip");
        // the batch-tiled layout interleaves the same data losslessly
        let t = CondensedTiled::from_condensed(&c);
        assert_eq!(t.to_condensed(), c, "seed {seed}: tiled roundtrip");
        assert_eq!(t.storage_bytes(), c.storage_bytes(), "seed {seed}: tiled bytes");
        assert_eq!(
            CondensedTiled::from_masked(&l.w, &l.mask).unwrap(),
            t,
            "seed {seed}: direct tiled construction"
        );
        // CSR roundtrip on the same matrix
        let csr = Csr::from_dense(&l.w);
        assert_eq!(csr.to_dense().data, l.w.data, "seed {seed}: csr roundtrip");
        assert_eq!(csr.nnz(), l.mask.nnz(), "seed {seed}: csr nnz");
    }
}

#[test]
fn prop_condensed_storage_accounting() {
    // storage_bytes must be exactly values + indices + active list:
    // n_active * k * (4 + 4) + n_active * 4 bytes, for any ablation level.
    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let mut l = rand_layer(&mut rng, true);
        let n = l.mask.neurons;
        let n_ablate = rng.below(n); // up to n-1 ablated
        for r in rng.choose_k(n, n_ablate) {
            for j in 0..l.mask.fan_in {
                l.mask.set(r, j, false);
                l.w.data[r * l.mask.fan_in + j] = 0.0;
            }
        }
        let c = Condensed::from_masked(&l.w, &l.mask).unwrap();
        let na = c.n_active();
        assert_eq!(na, n - n_ablate, "seed {seed}");
        assert_eq!(c.values.len(), na * c.k, "seed {seed}: values shape");
        assert_eq!(c.idx.len(), na * c.k, "seed {seed}: idx shape");
        assert_eq!(
            c.storage_bytes(),
            na * c.k * 8 + na * 4,
            "seed {seed}: storage accounting"
        );
        // condensed never stores more than the nnz demands
        assert_eq!(na * c.k, l.mask.nnz(), "seed {seed}: nnz");
    }
}

#[test]
fn condensed_all_rows_ablated() {
    // Every neuron ablated: the condensed form is empty but still
    // round-trips to the all-zero matrix/mask and accounts 0 bytes.
    let n = 12;
    let d = 20;
    let w = Tensor::zeros(&[n, d]);
    let m = Mask::from_tensor(Tensor::zeros(&[n, d]));
    let c = Condensed::from_masked(&w, &m).unwrap();
    assert_eq!(c.n_active(), 0);
    assert_eq!(c.k, 0);
    assert_eq!(c.storage_bytes(), 0);
    assert!(c.active.is_empty() && c.values.is_empty() && c.idx.is_empty());
    assert_eq!(c.to_dense().data, w.data);
    assert_eq!(c.to_mask().t.data, m.t.data);
    // same for the tiled layout
    let t = CondensedTiled::from_condensed(&c);
    assert_eq!(t.n_active(), 0);
    assert!(t.pairs.is_empty());
    assert_eq!(t.to_condensed(), c);
}

#[test]
fn condensed_k0_layer_forwards_empty() {
    // An all-ablated layer must still be constructible and serve a forward
    // pass (empty output) through the inference engine — in both the
    // plain and the batch-tiled representation.
    use srigl::inference::{CondensedLayer, CondensedTiledLayer, LinearKernel};
    let n = 6;
    let d = 10;
    let w = Tensor::zeros(&[n, d]);
    let m = Mask::from_tensor(Tensor::zeros(&[n, d]));
    let bias = vec![1.0f32; n];
    let layer = CondensedLayer::new(&w, &m, &bias).unwrap();
    let tiled = CondensedTiledLayer::new(&w, &m, &bias).unwrap();
    assert_eq!(LinearKernel::out_width(&layer), 0);
    assert_eq!(LinearKernel::out_width(&tiled), 0);
    for batch in [1usize, 3, 9] {
        let x = vec![0.5f32; batch * d];
        let mut out: Vec<f32> = vec![];
        LinearKernel::forward(&layer, &x, batch, &mut out, 2);
        assert!(out.is_empty());
        LinearKernel::forward(&tiled, &x, batch, &mut out, 2);
        assert!(out.is_empty());
    }
    assert_eq!(layer.c.storage_bytes(), 0);
    assert_eq!(tiled.t.storage_bytes(), 0);
}

#[test]
fn prop_erk_budget_exact() {
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let n_layers = 2 + rng.below(5);
        let layers: Vec<LayerShape> = (0..n_layers)
            .map(|i| {
                let dims = if rng.uniform() < 0.5 {
                    vec![4 + rng.below(64), 4 + rng.below(64)]
                } else {
                    vec![4 + rng.below(32), 2 + rng.below(16), 3, 3]
                };
                LayerShape { name: format!("l{i}"), dims }
            })
            .collect();
        let s = 0.5 + rng.uniform() * 0.45;
        let d = layer_densities(Distribution::Erk, &layers, s);
        let total: f64 = layers.iter().map(|l| l.numel() as f64).sum();
        let nnz: f64 = layers.iter().zip(&d).map(|(l, &di)| l.numel() as f64 * di).sum();
        assert!(
            ((1.0 - nnz / total) - s).abs() < 1e-9,
            "seed {seed}: ERK budget off (target {s})"
        );
        assert!(d.iter().all(|&x| x > 0.0 && x <= 1.0 + 1e-12), "seed {seed}: {d:?}");
        // constant fan-in targets stay in range and near the budget
        let ks = fan_in_targets(&layers, &d);
        for (l, &k) in layers.iter().zip(&ks) {
            assert!(k >= 1 && k <= l.fan_in(), "seed {seed}");
        }
        let ach = achieved_sparsity(&layers, &ks);
        assert!((ach - s).abs() < 0.2, "seed {seed}: rounding drift {ach} vs {s}");
    }
}

#[test]
fn prop_engine_kernels_agree() {
    use srigl::inference::{LayerBundle, LinearKernel};
    for seed in 0..30 {
        let mut rng = Rng::new(5000 + seed);
        let n = 8 + rng.below(64);
        let d = 8 + rng.below(128);
        let sparsity = 0.5 + rng.uniform() * 0.49;
        let ablated = rng.uniform() * 0.4;
        let bundle = LayerBundle::synth(n, d, sparsity, ablated, seed);
        let batch = 1 + rng.below(5);
        let threads = 1 + rng.below(4);
        let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();

        let mut dense_out = vec![0f32; batch * n];
        bundle.dense.forward(&x, batch, &mut dense_out, threads);
        let mut csr_out = vec![0f32; batch * n];
        bundle.csr.forward(&x, batch, &mut csr_out, threads);
        let na = bundle.condensed.out_width();
        let mut cond_out = vec![0f32; batch * na];
        bundle.condensed.forward(&x, batch, &mut cond_out, threads);

        for i in 0..batch * n {
            assert!(
                (dense_out[i] - csr_out[i]).abs() < 1e-3 * (1.0 + dense_out[i].abs()),
                "seed {seed} idx {i}: dense vs csr"
            );
        }
        for b in 0..batch {
            for (i, &r) in bundle.condensed.c.active.iter().enumerate() {
                let e = dense_out[b * n + r as usize];
                let g = cond_out[b * na + i];
                assert!(
                    (e - g).abs() < 1e-3 * (1.0 + e.abs()),
                    "seed {seed} b={b} r={r}: dense {e} vs condensed {g}"
                );
            }
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    fn rand_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let len = rng.below(12);
                Json::Str((0..len).map(|_| char::from(32 + rng.below(90) as u8)).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| rand_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..CASES {
        let mut rng = Rng::new(6000 + seed);
        let v = rand_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(back, v, "seed {seed}: {text}");
    }
}

#[test]
fn prop_drop_fraction_bounds() {
    use srigl::dst::UpdateSchedule;
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let total = 50 + rng.below(2000);
        let dt = 1 + rng.below(200);
        let s = UpdateSchedule::rigl_default(total, dt);
        for step in (0..total).step_by(7) {
            let f = s.drop_fraction(step);
            assert!((0.0..=0.3 + 1e-12).contains(&f), "seed {seed} step {step}: {f}");
            if step >= s.t_end() {
                assert_eq!(f, 0.0);
                assert!(!s.is_update_step(step));
            }
        }
    }
}
