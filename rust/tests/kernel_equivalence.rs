//! Kernel-equivalence suite: the five linear-layer representations
//! (dense / CSR / structured / condensed / condensed-tiled) must compute
//! the same function on the same masked weights — per layer and through a
//! full [`SparseModel`] stack — across ragged batch sizes
//! {1, 3, 7, 8, 9, 256} (non-multiples of the 8-wide tile exercise the
//! tiled kernel's remainder path) and thread counts {1, 4}, including a
//! heavy-ablation geometry.
//!
//! Tolerance: 1e-5 relative-ish (`|a-b| <= 1e-5 * (1 + max|a|,|b|)`); the
//! representations sum identical terms in different orders, so agreement is
//! limited only by f32 re-association. The SIMD-vs-scalar gap *within* one
//! representation is pinned much tighter, by the per-element ULP bound
//! documented in docs/KERNELS.md.

use srigl::inference::model::{Activation, LayerSpec, ModelLayer, Repr, SparseModel};
use srigl::inference::server::{serve_model, ServeConfig};
use srigl::inference::EngineBuilder;
use srigl::inference::{LayerBundle, LinearKernel};
use srigl::kernels::{ulp_diff, KernelKind, Microkernel};
use srigl::sparsity::Mask;
use srigl::tensor::Tensor;
use srigl::util::rng::Rng;

const TOL: f32 = 1e-5;

fn assert_close(a: f32, b: f32, ctx: &str) {
    let tol = TOL * (1.0 + a.abs().max(b.abs()));
    assert!((a - b).abs() <= tol, "{ctx}: {a} vs {b} (|diff| {} > {tol})", (a - b).abs());
}

/// Ragged batches around the tile width 8: below, exact, just above, and
/// a large multiple.
#[cfg(not(miri))]
const BATCHES: [usize; 6] = [1, 3, 7, 8, 9, 256];
#[cfg(not(miri))]
const THREADS: [usize; 2] = [1, 4];

/// Miri runs the same sweeps ~two orders of magnitude slower, so the CI
/// job keeps only the shapes that exercise distinct code paths: one
/// sub-tile batch, one ragged remainder, and both sides of the
/// single/multi-thread fork. Coverage of the unsafe surface (the
/// `get_unchecked` gathers) is identical — only repetition shrinks.
#[cfg(miri)]
const BATCHES: [usize; 3] = [1, 7, 9];
#[cfg(miri)]
const THREADS: [usize; 2] = [1, 2];

/// Random SRigL-shaped geometries: (n, d, sparsity, ablated_frac, seed).
/// The last entry ablates 85% of neurons — the compact forms shrink to a
/// handful of rows while dense/CSR keep full width.
#[cfg(not(miri))]
const GEOMETRIES: [(usize, usize, f64, f64, u64); 4] = [
    (64, 128, 0.9, 0.25, 1),
    (96, 48, 0.8, 0.4, 2),
    (33, 77, 0.95, 0.1, 3),
    (40, 64, 0.9, 0.85, 4),
];
/// Under Miri: one ordinary geometry plus the heavy-ablation one (the
/// compact-row bookkeeping is where an index bug would hide).
#[cfg(miri)]
const GEOMETRIES: [(usize, usize, f64, f64, u64); 2] =
    [(64, 128, 0.9, 0.25, 1), (40, 64, 0.9, 0.85, 4)];

#[test]
fn layer_representations_agree() {
    for &(n, d, sparsity, ablated, seed) in &GEOMETRIES {
        let bundle = LayerBundle::synth(n, d, sparsity, ablated, seed);
        let active = &bundle.structured.active;
        for &batch in &BATCHES {
            let mut rng = Rng::new(seed ^ 0xbeef);
            let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();

            let mut out_dense = vec![0f32; batch * n];
            bundle.dense.forward(&x, batch, &mut out_dense, 1);

            for &threads in &THREADS {
                // dense is representation-stable across thread counts
                let mut out_dt = vec![0f32; batch * n];
                bundle.dense.forward(&x, batch, &mut out_dt, threads);
                for i in 0..batch * n {
                    assert_close(out_dense[i], out_dt[i], &format!("dense t{threads} idx {i}"));
                }

                // CSR (same constant-fan-in pattern) matches dense everywhere
                let mut out_csr = vec![0f32; batch * n];
                bundle.csr.forward(&x, batch, &mut out_csr, threads);
                for i in 0..batch * n {
                    assert_close(
                        out_dense[i],
                        out_csr[i],
                        &format!("csr b{batch} t{threads} idx {i}"),
                    );
                }

                // compact forms match dense on the surviving neurons
                let na = bundle.structured.out_width();
                let mut out_s = vec![0f32; batch * na];
                bundle.structured.forward(&x, batch, &mut out_s, threads);
                let mut out_c = vec![0f32; batch * na];
                bundle.condensed.forward(&x, batch, &mut out_c, threads);
                let mut out_t = vec![0f32; batch * na];
                bundle.condensed_tiled.forward(&x, batch, &mut out_t, threads);
                for b in 0..batch {
                    for (j, &r) in active.iter().enumerate() {
                        let want = out_dense[b * n + r as usize];
                        let ctx = format!("b{batch} t{threads} row {r}");
                        assert_close(want, out_s[b * na + j], &format!("structured {ctx}"));
                        assert_close(want, out_c[b * na + j], &format!("condensed {ctx}"));
                        assert_close(want, out_t[b * na + j], &format!("condensed-tiled {ctx}"));
                    }
                }
            }
        }
    }
}

/// One layer's (w, mask, bias) with constant fan-in `k` and exactly
/// `ablate` fully-masked neurons — delegates to the engine's own synthesis
/// recipe (`inference::model::synth_layer`) so the suite exercises what
/// the engine ships. The +0.5 nudge makes the fraction floor to `ablate`
/// exactly despite f64 rounding.
fn rand_layer(n: usize, d: usize, k: usize, ablate: usize, rng: &mut Rng) -> (Tensor, Mask, Vec<f32>) {
    srigl::inference::model::synth_layer(
        n,
        d,
        1.0 - k as f64 / d as f64,
        (ablate as f64 + 0.5) / n as f64,
        rng,
    )
}

/// A whole stack built from the SAME weights in each of the five
/// representations (and a mixed stack) must produce identical outputs:
/// the model semantics (ablated neuron => 0, bias included) are
/// representation-independent.
#[test]
fn model_stacks_agree_across_representations() {
    let dims = [(40usize, 32usize, 5usize, 6usize), (32, 24, 4, 4), (24, 16, 3, 0)];
    let mut rng = Rng::new(99);
    let weights: Vec<(Tensor, Mask, Vec<f32>)> =
        dims.iter().map(|&(d, n, k, abl)| rand_layer(n, d, k, abl, &mut rng)).collect();

    let build = |reprs: [Repr; 3]| -> SparseModel {
        let layers: Vec<ModelLayer> = weights
            .iter()
            .zip(reprs)
            .enumerate()
            .map(|(i, ((w, m, b), repr))| {
                let act = if i == 2 { Activation::Identity } else { Activation::Relu };
                ModelLayer::from_weights(w, m, b, repr, act).unwrap()
            })
            .collect();
        SparseModel::new(layers).unwrap()
    };

    let reference = build([Repr::Dense, Repr::Dense, Repr::Dense]);
    let variants = [
        build([Repr::Csr, Repr::Csr, Repr::Csr]),
        build([Repr::Structured, Repr::Structured, Repr::Structured]),
        build([Repr::Condensed, Repr::Condensed, Repr::Condensed]),
        build([Repr::CondensedTiled, Repr::CondensedTiled, Repr::CondensedTiled]),
        build([Repr::Condensed, Repr::CondensedTiled, Repr::Structured]), // mixed per-layer
    ];

    for &batch in &BATCHES {
        let mut rng = Rng::new(7 ^ batch as u64);
        let x: Vec<f32> = (0..batch * 40).map(|_| rng.normal_f32()).collect();
        let mut sref = reference.make_scratch(batch);
        let want = reference.forward(&x, batch, &mut sref, 1).to_vec();
        for &threads in &THREADS {
            for (vi, v) in variants.iter().enumerate() {
                let mut s = v.make_scratch(batch);
                let got = v.forward(&x, batch, &mut s, threads);
                assert_eq!(got.len(), want.len());
                for i in 0..want.len() {
                    assert_close(
                        want[i],
                        got[i],
                        &format!("variant {vi} b{batch} t{threads} idx {i}"),
                    );
                }
            }
        }
    }
}

/// SIMD-vs-scalar is pinned per element: each available SIMD kind
/// (portable, and AVX2+FMA where detected) must agree with the scalar
/// reference oracle within the documented bound — **256 ULP, with an
/// absolute floor of `terms * f32::EPSILON`** (`terms` = the row's
/// reduction length: d for dense, fan-in k for the sparse forms). The
/// floor is the theoretical re-association envelope for O(1) operands —
/// near-zero cancellation makes ULP distance blow up while the absolute
/// gap stays inside it — and a real kernel bug (wrong index, dropped
/// term) lands ~5 orders of magnitude above it. Rationale in
/// docs/KERNELS.md. Engine conformance stays bit-for-bit *within* a
/// fixed kind; this test bounds the gap *across* kinds.
#[test]
#[cfg_attr(miri, ignore)] // AVX2 intrinsics aren't modeled by Miri; the gather
// unsafe surface is already covered by the agreement tests above
fn simd_kernels_match_scalar_within_ulp_bound() {
    const ULP_BOUND: u64 = 256;
    let (n, d) = (48usize, 512usize);
    let bundle = LayerBundle::synth(n, d, 0.9, 0.25, 11);
    let k_fan_in = bundle.condensed.c.k;
    let batch = 9; // one full tile + ragged remainder for the tiled layer
    let mut rng = Rng::new(123);
    let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();

    // layers rebuilt under a forced kind, each tagged with its reduction
    // length (the absolute floor scales with it)
    let run = |kind: KernelKind| -> Vec<(String, usize, Vec<f32>)> {
        let mk = Microkernel::of(kind);
        let mut dense = srigl::inference::DenseLayer::new(&bundle.w, bundle.bias.clone());
        dense.mk = mk;
        let mut csr = srigl::inference::CsrLayer::new(&bundle.w, bundle.bias.clone());
        csr.mk = mk;
        let mut cond =
            srigl::inference::CondensedLayer::new(&bundle.w, &bundle.mask, &bundle.bias).unwrap();
        cond.mk = mk;
        let mut tiled =
            srigl::inference::CondensedTiledLayer::new(&bundle.w, &bundle.mask, &bundle.bias)
                .unwrap();
        tiled.mk = mk;
        let kernels: Vec<(&str, usize, &dyn LinearKernel)> = vec![
            ("dense", d, &dense),
            ("csr", k_fan_in, &csr),
            ("condensed", k_fan_in, &cond),
            ("tiled", k_fan_in, &tiled),
        ];
        kernels
            .into_iter()
            .map(|(name, terms, k)| {
                let mut out = vec![0f32; batch * k.out_width()];
                k.forward(&x, batch, &mut out, 1);
                (name.to_string(), terms, out)
            })
            .collect()
    };

    let scalar = run(KernelKind::Scalar);
    for kind in [KernelKind::Portable, KernelKind::Avx2] {
        if !kind.available() {
            continue;
        }
        let simd = run(kind);
        for ((name, terms, want), (_, _, got)) in scalar.iter().zip(&simd) {
            assert_eq!(want.len(), got.len());
            let floor = *terms as f32 * f32::EPSILON;
            for (i, (w, g)) in want.iter().zip(got).enumerate() {
                let ulps = ulp_diff(*w, *g);
                assert!(
                    ulps <= ULP_BOUND || (w - g).abs() <= floor,
                    "{} {} idx {i}: scalar {w} vs {g} ({ulps} ULP, floor {floor:e})",
                    kind.name(),
                    name
                );
            }
        }
    }
}

/// Batch-position invariance at the bit level: the serving front-end
/// packs concurrent requests into one forward and pins packed-vs-direct
/// bit-for-bit, so a row's output must not depend on whether it landed in
/// a full tile, the ragged remainder, or a batch-1 forward — for every
/// representation, under the process-selected kernel.
#[test]
fn packed_rows_are_bitwise_position_invariant() {
    let (n, d) = (24usize, 40usize);
    let bundle = LayerBundle::synth(n, d, 0.85, 0.3, 21);
    let mut rng = Rng::new(31);
    let xrow: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    for kernel in bundle.kernels_same_matrix() {
        let ow = kernel.out_width();
        let mut solo = vec![0f32; ow];
        kernel.forward(&xrow, 1, &mut solo, 1);
        for &batch in &[3usize, 8, 9, 17] {
            for pos in [0usize, batch - 1] {
                let mut x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();
                x[pos * d..(pos + 1) * d].copy_from_slice(&xrow);
                let mut out = vec![0f32; batch * ow];
                kernel.forward(&x, batch, &mut out, 2);
                for r in 0..ow {
                    assert_eq!(
                        out[pos * ow + r].to_bits(),
                        solo[r].to_bits(),
                        "{} batch {batch} pos {pos} r {r}: packed vs solo",
                        kernel.name()
                    );
                }
            }
        }
    }
}

/// The worker pool must serve every request exactly once and stay
/// consistent when workers and intra-op threads are both > 1.
#[test]
#[cfg_attr(miri, ignore)] // wall-clock driven (interarrival pacing, latency
// percentiles); Miri's synthetic clock makes it meaningless and slow
fn pooled_serving_is_complete() {
    let spec = |n, act| LayerSpec {
        n,
        repr: Repr::Condensed,
        sparsity: 0.9,
        ablated_frac: 0.3,
        activation: act,
    };
    let model = SparseModel::synth(
        96,
        &[spec(64, Activation::Relu), spec(48, Activation::Relu), spec(16, Activation::Identity)],
        21,
    )
    .unwrap();
    for (workers, threads) in [(1usize, 1usize), (4, 1), (2, 4)] {
        let stats = serve_model(
            &model,
            &EngineBuilder::new().workers(workers).fixed_batch(8).threads(threads),
            &ServeConfig {
                n_requests: 256,
                mean_interarrival: std::time::Duration::ZERO,
                seed: 13,
            },
        )
        .unwrap();
        assert_eq!(stats.n, 256, "workers={workers} threads={threads}");
        assert!(stats.mean_batch >= 1.0);
        assert!(stats.p50_us.is_finite() && stats.p99_us >= stats.p50_us);
    }
}
