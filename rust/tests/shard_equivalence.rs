//! Sharded-vs-replicated equivalence suite: [`ShardedModel`] must compute
//! **bit-for-bit** the same outputs as the replicated
//! [`SparseModel::forward`] — the shard slices copy weight rows verbatim
//! and run the identical per-neuron arithmetic, so not even f32
//! re-association may differ. Pinned across:
//!
//! * shard counts {1, 2, 3} (plus a count exceeding the narrowest layer);
//! * all four representations, uniform and mixed per layer;
//! * batch sizes {1, 7, 256};
//! * layers with heavily ablated neurons (zero-cost rows in the plan);
//! * intra-shard thread counts {1, 4}.

use srigl::inference::model::{Activation, LayerSpec, Repr, SparseModel};
use srigl::inference::shard::{ShardPlan, ShardedModel};
use srigl::util::rng::Rng;

const BATCHES: [usize; 3] = [1, 7, 256];
const SHARDS: [usize; 3] = [1, 2, 3];

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: idx {i}: {g} vs {w} (must be bit-for-bit)");
    }
}

fn stack(reprs: &[Repr], ablated: f64, seed: u64) -> SparseModel {
    let n_layers = reprs.len();
    let widths = [48usize, 32, 16];
    let specs: Vec<LayerSpec> = reprs
        .iter()
        .enumerate()
        .map(|(i, &repr)| LayerSpec {
            n: widths[i % widths.len()],
            repr,
            sparsity: 0.9,
            ablated_frac: ablated,
            activation: if i + 1 == n_layers { Activation::Identity } else { Activation::Relu },
        })
        .collect();
    SparseModel::synth(64, &specs, seed).unwrap()
}

fn check(model: &SparseModel, sharded: &ShardedModel, ctx: &str) {
    for &batch in &BATCHES {
        let mut rng = Rng::new(0xE0 ^ batch as u64);
        let x: Vec<f32> = (0..batch * model.in_width()).map(|_| rng.normal_f32()).collect();
        for threads in [1usize, 4] {
            let want = model.forward_vec(&x, batch, 1);
            let got = sharded.forward_vec(&x, batch, threads);
            assert_bits_eq(&got, &want, &format!("{ctx} b{batch} t{threads}"));
        }
    }
}

#[test]
fn sharded_matches_replicated_all_reprs() {
    for repr in Repr::ALL {
        let model = stack(&[repr; 3], 0.25, 7);
        for &shards in &SHARDS {
            let sharded = ShardedModel::from_model(&model, shards).unwrap();
            assert_eq!(sharded.shards(), shards.max(1));
            check(&model, &sharded, &format!("{} s{shards}", repr.name()));
        }
    }
}

#[test]
fn sharded_matches_replicated_mixed_stack() {
    let model = stack(&[Repr::Condensed, Repr::Csr, Repr::Structured, Repr::Dense], 0.3, 21);
    for &shards in &SHARDS {
        let sharded = ShardedModel::from_model(&model, shards).unwrap();
        check(&model, &sharded, &format!("mixed s{shards}"));
    }
}

#[test]
fn sharded_matches_with_heavy_ablation() {
    // over half the neurons ablated: plans must absorb long zero-cost runs
    for repr in [Repr::Condensed, Repr::Structured] {
        let model = stack(&[repr; 3], 0.6, 33);
        for &shards in &SHARDS {
            let sharded = ShardedModel::from_model(&model, shards).unwrap();
            check(&model, &sharded, &format!("{} ablated s{shards}", repr.name()));
        }
    }
}

#[test]
fn shard_count_exceeding_narrowest_layer() {
    // narrowest layer has 2 neurons; 5 shards leave >= 3 of them empty
    // there, and every empty shard must still synchronize correctly
    let specs = [
        LayerSpec {
            n: 24,
            repr: Repr::Condensed,
            sparsity: 0.8,
            ablated_frac: 0.25,
            activation: Activation::Relu,
        },
        LayerSpec {
            n: 2,
            repr: Repr::Condensed,
            sparsity: 0.5,
            ablated_frac: 0.0,
            activation: Activation::Relu,
        },
        LayerSpec {
            n: 8,
            repr: Repr::Dense,
            sparsity: 0.5,
            ablated_frac: 0.0,
            activation: Activation::Identity,
        },
    ];
    let model = SparseModel::synth(16, &specs, 3).unwrap();
    let sharded = ShardedModel::from_model(&model, 5).unwrap();
    let narrow: Vec<usize> = (0..5).map(|s| sharded.plan().range(1, s).len()).collect();
    assert_eq!(narrow.iter().sum::<usize>(), 2);
    assert!(narrow.iter().filter(|&&w| w == 0).count() >= 3, "{narrow:?}");
    check(&model, &sharded, "narrow s5");
}

#[test]
fn balanced_plan_ranges_cover_each_layer() {
    let model = stack(&[Repr::Condensed; 3], 0.4, 9);
    for &shards in &[2usize, 3, 7] {
        let plan = ShardPlan::balanced(&model, shards);
        assert_eq!(plan.shards(), shards);
        assert_eq!(plan.layers(), model.depth());
        for (li, layer) in model.layers().iter().enumerate() {
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for s in 0..shards {
                let r = plan.range(li, s);
                assert_eq!(r.start, prev_end, "contiguous");
                covered += r.len();
                prev_end = r.end;
            }
            assert_eq!(covered, layer.out_full_width(), "layer {li} fully covered");
            // balanced within one neuron's worth of stored weights of
            // ideal is not guaranteed by the greedy, but gross imbalance
            // (> 1.75x ideal) would mean the plan ignored the costs
            assert!(
                plan.imbalance(&model, li) < 1.75,
                "layer {li} imbalance {}",
                plan.imbalance(&model, li)
            );
        }
    }
}
