//! Socket-serving demo: spin up the network front-end on a loopback port,
//! drive it with the wire-protocol client, and show the three response
//! paths — computed, cached, and backpressured (Busy).
//!
//! Run: cargo run --release --example socket_serving -- [--sparsity 0.9]

use std::sync::Arc;

use anyhow::Result;

use srigl::exp::timings::ablated_frac_for;
use srigl::inference::{frontend, Activation, EngineBuilder, LayerSpec, Repr, SparseModel};
use srigl::net::{Client, Reply};
use srigl::util::cli::Args;
use srigl::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let spec = |n, act| LayerSpec {
        n,
        repr: Repr::Condensed,
        sparsity,
        ablated_frac: ablated_frac_for(sparsity),
        activation: act,
    };
    let model = Arc::new(SparseModel::synth(
        256,
        &[spec(192, Activation::Relu), spec(128, Activation::Relu), spec(32, Activation::Identity)],
        42,
    )?);
    println!("model: {}", model.describe());

    let handle = frontend::spawn(
        Arc::clone(&model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(2)
            .adaptive(8)
            .queue_capacity(256)
            .cache_capacity(128)
            .retry_after_ms(2),
    )?;
    println!("front-end listening on {} (2 workers, adaptive batching, cache 128)\n", handle.addr());

    let mut client = Client::connect(handle.addr())?;
    let mut rng = Rng::new(7);
    let d = model.in_width();

    // computed path: fresh inputs, cross-checked against the direct forward
    let mut worst: f32 = 0.0;
    for _ in 0..32 {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let served = client.infer_retrying(1, &x, 20)?;
        let direct = model.forward_vec(&x, 1, 1);
        for (s, dr) in served.iter().zip(&direct) {
            worst = worst.max((s - dr).abs());
        }
    }
    println!("32 computed requests: max |served - direct| = {worst:.1e} (expect exactly 0)");

    // cached path: replaying a payload is answered from the LRU
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let a = client.infer_retrying(1, &x, 20)?;
    let b = client.infer_retrying(1, &x, 20)?;
    println!("replayed payload: identical answers = {}", a == b);

    // Busy path: what a rejection looks like to a client
    match client.infer(1, &x)? {
        Reply::Output(_) => println!("(queue had room — no Busy to show this run)"),
        Reply::Busy { retry_after_ms } => println!("got Busy, retry after {retry_after_ms}ms"),
    }

    let stats = handle.stop();
    println!(
        "\nserver stats: served={} cache_hits={} rejected={} dropped={} connections={} mean_batch={:.2}",
        stats.served,
        stats.cache_hits,
        stats.rejected,
        stats.dropped_responses,
        stats.connections_total,
        stats.latency.mean_batch
    );
    println!(
        "latency (server-side, queued requests): p50={:.1}us p99={:.1}us",
        stats.latency.p50_us, stats.latency.p99_us
    );
    Ok(())
}
