//! gamma_sal ablation-threshold sweep (paper Figs. 8/9): trains the same
//! model at several ablation thresholds and reports accuracy + the final
//! widths, showing how gamma_sal steers learned structure.
//!
//! Run: cargo run --release --example gamma_sal_sweep --
//!        [--model mlp_proxy] [--sparsity 0.95] [--steps 200]

use anyhow::Result;

use srigl::sparsity::Distribution;
use srigl::stats::{active_neuron_fraction, LayerTopology};
use srigl::train::{LrSchedule, Method, Session, TrainConfig};
use srigl::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let model = args.get_or("model", "mlp_proxy");
    let sparsity: f64 = args.parse_or("sparsity", 0.95)?;
    let steps: usize = args.parse_or("steps", 200)?;
    let gammas: Vec<f64> = args.list_or("gammas", &[0.0, 0.3, 0.5, 0.9])?;
    let seed: u64 = args.parse_or("seed", 0)?;

    let sess = Session::open()?;
    println!(
        "gamma_sal sweep: {model} @ {:.0}% sparsity, {steps} steps (gamma=0 row = ablation off)",
        sparsity * 100.0
    );
    println!("{:>6}  {:>9}  {:>14}  {:>8}  topology", "gamma", "accuracy", "active neurons", "k");
    for &g in &gammas {
        let method = if g == 0.0 {
            Method::SRigL { ablation: false, gamma_sal: 0.0 }
        } else {
            Method::SRigL { ablation: true, gamma_sal: g }
        };
        let cfg = TrainConfig {
            model: model.clone(),
            method,
            sparsity,
            distribution: Distribution::Erk,
            total_steps: steps,
            delta_t: (steps / 15).max(5),
            alpha: 0.3,
            lr: LrSchedule::step_decay(0.1, &[steps / 2, 3 * steps / 4], 0.2),
            grad_accum: 1,
            seed,
            eval_batches: 8,
            dense_first_layer: false,
        };
        let mut tr = sess.trainer(cfg)?;
        let rep = tr.run()?;
        let tops: Vec<LayerTopology> = tr
            .mask_stats()
            .iter()
            .map(|(n, c)| LayerTopology::from_counts(n, c))
            .collect();
        let widths: Vec<String> =
            tops.iter().map(|t| format!("{}/{}", t.active_neurons, t.neurons)).collect();
        println!(
            "{:>6.2}  {:>8.1}%  {:>13.1}%  {:>8}  [{}]",
            g,
            rep.eval_metric * 100.0,
            active_neuron_fraction(&tops) * 100.0,
            tops.iter().map(|t| t.fan_in_max).max().unwrap_or(0),
            widths.join(", ")
        );
    }
    println!("\nExpected shape (paper App. E): accuracy roughly flat in gamma for MLP/CNN\n(min-salient clamp), while higher gamma ablates more neurons and raises k.");
    Ok(())
}
