//! Quickstart: train a tiny MLP with SRigL, inspect the learned structure,
//! and run the resulting condensed layer through the native inference
//! engine — the whole public API in ~80 lines.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use srigl::inference::{CondensedLayer, DenseLayer, LinearKernel};
use srigl::sparsity::Distribution;
use srigl::stats::LayerTopology;
use srigl::train::{LrSchedule, Method, Session, TrainConfig};

fn main() -> Result<()> {
    // 1) Open a session: PJRT CPU client + AOT artifact manifest.
    let sess = Session::open()?;

    // 2) Configure SRigL: 90% sparse, ERK layer densities, neuron ablation
    //    with gamma_sal = 0.3 (the paper's CNN setting).
    let steps = 300;
    let cfg = TrainConfig {
        model: "mlp_tiny".into(),
        method: Method::SRigL { ablation: true, gamma_sal: 0.3 },
        sparsity: 0.9,
        distribution: Distribution::Erk,
        total_steps: steps,
        delta_t: 20,
        alpha: 0.3,
        lr: LrSchedule::step_decay(0.1, &[150, 225], 0.2),
        grad_accum: 1,
        seed: 0,
        eval_batches: 16,
        dense_first_layer: false,
    };

    // 3) Train. Every step executes the AOT-compiled JAX train_step (which
    //    itself calls the Pallas masked-matmul kernel); every delta_t steps
    //    the rust SRigL updater evolves the topology.
    let mut trainer = sess.trainer(cfg)?;
    println!("training mlp_tiny with SRigL @ 90% sparsity ({steps} steps)...");
    let report = trainer.run()?;
    println!(
        "loss {:.3} -> {:.3} | eval accuracy {:.1}% | sparsity {:.1}% | {:.1} steps/s",
        report.losses.first().unwrap(),
        report.losses.last().unwrap(),
        report.eval_metric * 100.0,
        report.final_sparsity * 100.0,
        report.throughput,
    );

    // 4) Inspect the learned structure: constant fan-in + ablated neurons.
    for (name, counts) in trainer.mask_stats() {
        let t = LayerTopology::from_counts(&name, &counts);
        println!(
            "  {name}: {}/{} neurons active, constant fan-in {}",
            t.active_neurons, t.neurons, t.fan_in_max
        );
    }

    // 5) Export layer 0 in the condensed representation (Algorithm 1) and
    //    time it against the dense baseline in the native engine.
    let cond = trainer.export_condensed(0)?;
    println!(
        "condensed layer 0: {} active neurons x k={} ({} bytes vs {} dense)",
        cond.n_active(),
        cond.k,
        cond.storage_bytes(),
        cond.n_orig * cond.d * 4,
    );
    let dense_w = cond.to_dense();
    let bias = vec![0f32; cond.n_orig];
    let mask = cond.to_mask();
    let dense = DenseLayer::new(&dense_w, bias.clone());
    let condensed = CondensedLayer::new(&dense_w, &mask, &bias)?;

    let x: Vec<f32> = (0..cond.d).map(|i| (i as f32 * 0.1).sin()).collect();
    let mut out_d = vec![0f32; dense.out_width()];
    let mut out_c = vec![0f32; condensed.out_width()];
    let t0 = std::time::Instant::now();
    for _ in 0..5000 {
        dense.forward(&x, 1, &mut out_d, 1);
    }
    let dense_us = t0.elapsed().as_secs_f64() * 1e6 / 5000.0;
    let t0 = std::time::Instant::now();
    for _ in 0..5000 {
        condensed.forward(&x, 1, &mut out_c, 1);
    }
    let cond_us = t0.elapsed().as_secs_f64() * 1e6 / 5000.0;
    println!(
        "online inference: dense {dense_us:.2}us/call, condensed {cond_us:.2}us/call ({:.1}x)",
        dense_us / cond_us
    );

    // numerics agree on the active rows
    let mut ok = true;
    for (i, &r) in condensed.c.active.iter().enumerate() {
        if (out_c[i] - out_d[r as usize]).abs() > 1e-4 {
            ok = false;
        }
    }
    println!("condensed == dense on active neurons: {}", if ok { "OK" } else { "MISMATCH" });
    Ok(())
}
