//! Condensed-representation inference demo (paper §4.4): builds the exact
//! Fig. 4 layer geometry (ViT-B/16 FF, 768x3072), compares the four
//! representations for online and batched inference, and then serves a
//! Poisson request stream through the online-inference server — including
//! the AOT Pallas condensed kernel via PJRT for cross-checking numerics.
//!
//! Run: cargo run --release --example condensed_inference -- [--sparsity 0.9]

use anyhow::Result;

use srigl::bench::{bench5, print_table};
use srigl::exp::timings::{ablated_frac_for, VIT_FF_D, VIT_FF_N};
use srigl::inference::server::{serve, serve_model, ServeConfig};
use srigl::inference::{Activation, EngineBuilder, LayerBundle, LayerSpec, LinearKernel, Repr, SparseModel};
use srigl::runtime::{i32s_to_lit, lit_to_tensor, tensor_to_lit, Manifest, Runtime};
use srigl::tensor::Tensor;
use srigl::util::cli::Args;
use srigl::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sparsity, ablated_frac_for(sparsity), 42);
    println!(
        "ViT FF layer {VIT_FF_N}x{VIT_FF_D} @ {:.0}% sparsity, k={}, {} / {} neurons active",
        sparsity * 100.0,
        bundle.condensed.c.k,
        bundle.condensed.c.n_active(),
        VIT_FF_N
    );
    println!(
        "storage: dense {} KiB | csr {} KiB | condensed {} KiB",
        VIT_FF_N * VIT_FF_D * 4 / 1024,
        bundle.csr.csr.storage_bytes() / 1024,
        bundle.condensed.c.storage_bytes() / 1024
    );

    // --- raw kernel timings, batch 1 and 32 ---
    let mut rng = Rng::new(7);
    for batch in [1usize, 32] {
        let x: Vec<f32> = (0..batch * VIT_FF_D).map(|_| rng.normal_f32()).collect();
        let ms: Vec<_> = bundle
            .kernels()
            .iter()
            .map(|k| {
                let mut out = vec![0f32; batch * k.out_width()];
                bench5(k.name(), || k.forward(&x, batch, &mut out, 1))
            })
            .collect();
        print_table(&format!("batch {batch} (median of 5 runs)"), &ms, Some("dense"));
    }

    // --- online-inference server ---
    println!("\nonline-inference server (500 requests, Poisson arrivals):");
    for kernel in bundle.kernels() {
        let stats = serve(
            kernel,
            &EngineBuilder::online(),
            &ServeConfig {
                n_requests: 500,
                mean_interarrival: std::time::Duration::from_micros(100),
                seed: 3,
            },
        );
        println!(
            "  {:<11} p50={:>7.1}us p99={:>7.1}us throughput={:>6.0} req/s",
            kernel.name(),
            stats.p50_us,
            stats.p99_us,
            stats.throughput_rps
        );
    }

    // --- multi-layer model through the worker-pool server ---
    let spec = |n, repr, act| LayerSpec {
        n,
        repr,
        sparsity,
        ablated_frac: ablated_frac_for(sparsity),
        activation: act,
    };
    let model = SparseModel::synth(
        VIT_FF_D,
        &[
            spec(VIT_FF_N, Repr::Condensed, Activation::Relu),
            spec(VIT_FF_N, Repr::Condensed, Activation::Relu),
            spec(256, Repr::Condensed, Activation::Identity),
        ],
        42,
    )?;
    println!("\nworker-pool serving, 3-layer condensed model {}:", model.describe());
    for workers in [1usize, 4] {
        let stats = serve_model(
            &model,
            &EngineBuilder::new().workers(workers).fixed_batch(8),
            &ServeConfig {
                n_requests: 400,
                mean_interarrival: std::time::Duration::ZERO,
                seed: 5,
            },
        )?;
        println!(
            "  workers={workers}  p50={:>7.1}us p99={:>7.1}us mean_batch={:.1} throughput={:>6.0} req/s",
            stats.p50_us, stats.p99_us, stats.mean_batch, stats.throughput_rps
        );
    }

    // --- cross-check the AOT Pallas condensed kernel (L1) via PJRT ---
    let Ok(man) = Manifest::load_default() else {
        println!("\n(skipping XLA cross-check: no artifacts — run `make artifacts`)");
        return Ok(());
    };
    if let Some(e) = man.condensed.get("cond_vitff_s90_b1") {
        if (e.k as f64 - (1.0 - sparsity) * VIT_FF_D as f64).abs() < 1.0 {
            let rt = Runtime::cpu()?;
            let prog = rt.load(&man.dir.join(&e.file))?;
            // feed the *same* condensed weights (truncated/padded to n rows)
            let c = &bundle.condensed.c;
            let rows = e.n.min(c.n_active());
            let mut w = vec![0f32; e.n * e.k];
            let mut idx = vec![0i32; e.n * e.k];
            for r in 0..rows {
                for j in 0..e.k {
                    w[r * e.k + j] = c.values[r * c.k + j];
                    idx[r * e.k + j] = c.idx[r * c.k + j] as i32;
                }
            }
            let x = Tensor::normal(&[1, e.d], 1.0, &mut Rng::new(9));
            let out = prog.run(&[
                tensor_to_lit(&x)?,
                tensor_to_lit(&Tensor::from_vec(&[e.n, e.k], w))?,
                i32s_to_lit(&[e.n, e.k], &idx)?,
            ])?;
            let xla_out = lit_to_tensor(&out[0], &[1, e.n])?;
            // native engine on the same inputs
            let mut native = vec![0f32; bundle.condensed.out_width()];
            bundle.condensed.forward(&x.data, 1, &mut native, 1);
            let mut max_err = 0f32;
            for r in 0..rows {
                max_err = max_err.max((xla_out.data[r] - (native[r] - bundle.condensed.bias[r])).abs());
            }
            println!("\nAOT Pallas kernel vs native engine: max |diff| = {max_err:.2e} over {rows} neurons");
            anyhow::ensure!(max_err < 1e-3, "XLA/native mismatch");
        } else {
            println!("\n(skipping XLA cross-check: artifact k={} != sparsity {:.0}%)", e.k, sparsity * 100.0);
        }
    }
    Ok(())
}
