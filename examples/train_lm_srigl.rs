//! End-to-end validation driver (DESIGN.md §5 "e2e"): train a causal
//! transformer LM with SRigL sparse-to-sparse training on a synthetic
//! Markov corpus for a few hundred steps and log the loss curve.
//!
//! This proves all three layers compose on a real training workload:
//!   L3 rust loop + SRigL topology updates
//!   L2 AOT JAX transformer fwd/bwd (train_step / dense_grad)
//!   L1 Pallas-kerneled artifacts through the same PJRT runtime
//!
//! The Markov chain has branching factor 4 over a 256-token vocabulary,
//! so loss should descend from ~ln(256) ≈ 5.5 toward ~ln(4) ≈ 1.39.
//!
//! Run: cargo run --release --example train_lm_srigl -- [--model lm_small]
//!      [--steps 300] [--sparsity 0.9] [--gamma 0.3]

use anyhow::Result;

use srigl::sparsity::Distribution;
use srigl::stats::LayerTopology;
use srigl::train::{LrSchedule, Method, Session, TrainConfig};
use srigl::util::cli::Args;
use srigl::util::json::{arr, num, obj, s, Json};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let model = args.get_or("model", "lm_small");
    let steps: usize = args.parse_or("steps", 300)?;
    let sparsity: f64 = args.parse_or("sparsity", 0.9)?;
    let gamma: f64 = args.parse_or("gamma", 0.3)?;
    let seed: u64 = args.parse_or("seed", 0)?;

    let sess = Session::open()?;
    let cfg = TrainConfig {
        model: model.clone(),
        method: Method::SRigL { ablation: true, gamma_sal: gamma },
        sparsity,
        distribution: Distribution::Uniform, // paper uses uniform for transformers
        total_steps: steps,
        delta_t: (steps / 15).max(5),
        alpha: 0.3,
        lr: LrSchedule::WarmupCosine { max: 0.08, warmup: steps / 10 },
        grad_accum: 1,
        seed,
        eval_batches: 8,
        dense_first_layer: false,
    };
    let mut tr = sess.trainer(cfg)?;
    println!(
        "e2e: {model} ({} params, {} sparse tensors) / SRigL @ {:.0}% / {steps} steps",
        tr.entry.param_count,
        tr.sparse_idx.len(),
        sparsity * 100.0
    );
    println!("loss floor: untrained ~= ln(256) = 5.55, Markov entropy ~= ln(4) = 1.39\n");

    let report = tr.run()?;

    // Print the loss curve, decimated to ~25 points.
    let n = report.losses.len();
    let stride = (n / 25).max(1);
    println!("step   loss");
    for i in (0..n).step_by(stride) {
        let bar_len = ((report.losses[i] / 6.0) * 50.0).clamp(0.0, 50.0) as usize;
        println!("{:>5}  {:>6.3} {}", i, report.losses[i], "#".repeat(bar_len));
    }
    println!("\neval loss = {:.4} nats (chance {:.2}, floor ~1.39)", report.eval_metric, (256f64).ln());
    println!(
        "final sparsity {:.1}% | ITOP {:.3} | {:.1}s total ({:.2} steps/s)",
        report.final_sparsity * 100.0,
        report.itop_rate,
        report.wall_s,
        report.throughput
    );
    for (name, counts) in tr.mask_stats() {
        let t = LayerTopology::from_counts(&name, &counts);
        println!(
            "  {name}: {}/{} active, k={} (fan-in var {:.1})",
            t.active_neurons, t.neurons, t.fan_in_max, t.fan_in_var
        );
    }

    // Persist the curve for EXPERIMENTS.md.
    std::fs::create_dir_all("results")?;
    let curve: Vec<Json> = report.losses.iter().map(|&l| num(l as f64)).collect();
    std::fs::write(
        "results/lm_loss_curve.json",
        obj(vec![
            ("model", s(&model)),
            ("sparsity", num(sparsity)),
            ("steps", num(steps as f64)),
            ("eval_loss", num(report.eval_metric)),
            ("losses", arr(curve)),
        ])
        .to_string(),
    )?;
    println!("\n[loss curve -> results/lm_loss_curve.json]");

    let first = *report.losses.first().unwrap() as f64;
    let last = *report.losses.last().unwrap() as f64;
    anyhow::ensure!(last < first * 0.7, "loss did not descend: {first} -> {last}");
    println!("E2E VALIDATION PASSED: loss descended {first:.3} -> {last:.3}");
    Ok(())
}
