//! Model-checked atomics. Every access is a scheduler decision point,
//! so all interleavings of atomic operations are explored — under
//! **sequential consistency**: the vendored checker does not model
//! Relaxed/Acquire/Release reordering (crates.io loom does). A model
//! that passes here proves its interleaving logic, not its memory
//! orderings; the TSan CI job covers the latter on real hardware.

pub use std::sync::atomic::Ordering;

use crate::rt;

macro_rules! atomic {
    ($name:ident, $os:ty, $ty:ty) => {
        pub struct $name($os);

        impl $name {
            pub fn new(v: $ty) -> $name {
                $name(<$os>::new(v))
            }

            pub fn load(&self, _order: Ordering) -> $ty {
                rt::yield_point();
                self.0.load(Ordering::SeqCst)
            }

            pub fn store(&self, v: $ty, _order: Ordering) {
                rt::yield_point();
                self.0.store(v, Ordering::SeqCst)
            }

            pub fn swap(&self, v: $ty, _order: Ordering) -> $ty {
                rt::yield_point();
                self.0.swap(v, Ordering::SeqCst)
            }

            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                _success: Ordering,
                _failure: Ordering,
            ) -> Result<$ty, $ty> {
                rt::yield_point();
                self.0.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
            }

            pub fn into_inner(self) -> $ty {
                self.0.into_inner()
            }
        }
    };
}

atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);

macro_rules! atomic_arith {
    ($name:ident, $ty:ty) => {
        impl $name {
            pub fn fetch_add(&self, v: $ty, _order: Ordering) -> $ty {
                rt::yield_point();
                self.0.fetch_add(v, Ordering::SeqCst)
            }

            pub fn fetch_sub(&self, v: $ty, _order: Ordering) -> $ty {
                rt::yield_point();
                self.0.fetch_sub(v, Ordering::SeqCst)
            }

            pub fn fetch_max(&self, v: $ty, _order: Ordering) -> $ty {
                rt::yield_point();
                self.0.fetch_max(v, Ordering::SeqCst)
            }

            pub fn fetch_min(&self, v: $ty, _order: Ordering) -> $ty {
                rt::yield_point();
                self.0.fetch_min(v, Ordering::SeqCst)
            }
        }
    };
}

atomic_arith!(AtomicU32, u32);
atomic_arith!(AtomicU64, u64);
atomic_arith!(AtomicUsize, usize);

/// A fence is a decision point; ordering effects are SeqCst-collapsed.
pub fn fence(_order: Ordering) {
    rt::yield_point();
    std::sync::atomic::fence(Ordering::SeqCst);
}
