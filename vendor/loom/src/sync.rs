//! Model-checked stand-ins for `std::sync` types. Each operation is a
//! scheduler decision point; blocking operations park the thread in the
//! scheduler (so a waiter that can never be woken is reported as a
//! deadlock, not spun forever). Poisoning is not modeled: a panicking
//! thread aborts the whole execution, so `lock()` always returns `Ok`.

pub use std::sync::Arc;
use std::sync::LockResult;
use std::sync::Mutex as OsMutex;
use std::sync::MutexGuard as OsMutexGuard;
use std::sync::RwLock as OsRwLock;
use std::sync::RwLockReadGuard as OsRwLockReadGuard;
use std::sync::RwLockWriteGuard as OsRwLockWriteGuard;

use crate::rt;

pub mod atomic;

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

struct MutexState {
    locked: bool,
    waiters: Vec<usize>,
}

/// A mutex whose lock/unlock edges are schedule decision points.
pub struct Mutex<T> {
    st: OsMutex<MutexState>,
    data: OsMutex<T>,
}

pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<OsMutexGuard<'a, T>>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex { st: OsMutex::new(MutexState { locked: false, waiters: Vec::new() }), data: OsMutex::new(t) }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        rt::yield_point();
        let me = rt::current_tid();
        loop {
            let acquired = {
                let mut s = self.st.lock().unwrap();
                if s.locked {
                    s.waiters.push(me);
                    false
                } else {
                    s.locked = true;
                    true
                }
            };
            if acquired {
                let inner = self.data.lock().unwrap();
                return Ok(MutexGuard { lock: self, inner: Some(inner) });
            }
            rt::block("mutex lock");
        }
    }

    pub fn try_lock(&self) -> Result<MutexGuard<'_, T>, std::sync::TryLockError<MutexGuard<'_, T>>> {
        rt::yield_point();
        let mut s = self.st.lock().unwrap();
        if s.locked {
            Err(std::sync::TryLockError::WouldBlock)
        } else {
            s.locked = true;
            drop(s);
            let inner = self.data.lock().unwrap();
            Ok(MutexGuard { lock: self, inner: Some(inner) })
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        Ok(self.data.into_inner().unwrap())
    }

    /// Release the logical lock and wake every waiter (they re-race;
    /// the scheduler explores the acquisition orders).
    fn raw_unlock(&self) {
        let waiters = {
            let mut s = self.st.lock().unwrap();
            s.locked = false;
            std::mem::take(&mut s.waiters)
        };
        for w in waiters {
            rt::unblock(w);
        }
    }
}

impl<'a, T> MutexGuard<'a, T> {
    /// Condvar support: release without dropping, returning the lock.
    fn dismantle(mut self) -> &'a Mutex<T> {
        self.inner.take();
        let lock = self.lock;
        std::mem::forget(self);
        lock.raw_unlock();
        lock
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        self.inner.take();
        self.lock.raw_unlock();
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard still holds the data lock")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard still holds the data lock")
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// A condition variable with exact (non-spurious) wakeups: a thread
/// parked in `wait` runs again only after a notify — so a lost wakeup
/// shows up as a loom deadlock.
pub struct Condvar {
    st: OsMutex<Vec<usize>>,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { st: OsMutex::new(Vec::new()) }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let me = rt::current_tid();
        // Register *before* releasing the mutex: the registration and the
        // release are atomic with respect to decision points, matching
        // the release-and-sleep atomicity of a real condvar.
        self.st.lock().unwrap().push(me);
        let lock = guard.dismantle();
        rt::block("condvar wait");
        lock.lock()
    }

    pub fn notify_one(&self) {
        let woken = {
            let mut s = self.st.lock().unwrap();
            if s.is_empty() {
                None
            } else {
                Some(s.remove(0))
            }
        };
        if let Some(w) = woken {
            rt::unblock(w);
        }
        rt::yield_point();
    }

    pub fn notify_all(&self) {
        let woken = std::mem::take(&mut *self.st.lock().unwrap());
        for w in woken {
            rt::unblock(w);
        }
        rt::yield_point();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

// ---------------------------------------------------------------------------
// RwLock
// ---------------------------------------------------------------------------

struct RwState {
    readers: usize,
    writer: bool,
    waiters: Vec<usize>,
}

/// A readers-writer lock whose acquire/release edges are decision points.
pub struct RwLock<T> {
    st: OsMutex<RwState>,
    data: OsRwLock<T>,
}

pub struct RwLockReadGuard<'a, T> {
    lock: &'a RwLock<T>,
    inner: Option<OsRwLockReadGuard<'a, T>>,
}

pub struct RwLockWriteGuard<'a, T> {
    lock: &'a RwLock<T>,
    inner: Option<OsRwLockWriteGuard<'a, T>>,
}

impl<T> RwLock<T> {
    pub fn new(t: T) -> RwLock<T> {
        RwLock {
            st: OsMutex::new(RwState { readers: 0, writer: false, waiters: Vec::new() }),
            data: OsRwLock::new(t),
        }
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        rt::yield_point();
        let me = rt::current_tid();
        loop {
            let acquired = {
                let mut s = self.st.lock().unwrap();
                if s.writer {
                    s.waiters.push(me);
                    false
                } else {
                    s.readers += 1;
                    true
                }
            };
            if acquired {
                let inner = self.data.read().unwrap();
                return Ok(RwLockReadGuard { lock: self, inner: Some(inner) });
            }
            rt::block("rwlock read");
        }
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        rt::yield_point();
        let me = rt::current_tid();
        loop {
            let acquired = {
                let mut s = self.st.lock().unwrap();
                if s.writer || s.readers > 0 {
                    s.waiters.push(me);
                    false
                } else {
                    s.writer = true;
                    true
                }
            };
            if acquired {
                let inner = self.data.write().unwrap();
                return Ok(RwLockWriteGuard { lock: self, inner: Some(inner) });
            }
            rt::block("rwlock write");
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        Ok(self.data.into_inner().unwrap())
    }

}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        self.inner.take();
        let waiters = {
            let mut s = self.lock.st.lock().unwrap();
            s.readers -= 1;
            if s.readers == 0 { std::mem::take(&mut s.waiters) } else { Vec::new() }
        };
        for w in waiters {
            rt::unblock(w);
        }
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        self.inner.take();
        let waiters = {
            let mut s = self.lock.st.lock().unwrap();
            s.writer = false;
            std::mem::take(&mut s.waiters)
        };
        for w in waiters {
            rt::unblock(w);
        }
    }
}

impl<T> std::ops::Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard still holds the data lock")
    }
}

impl<T> std::ops::Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard still holds the data lock")
    }
}

impl<T> std::ops::DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard still holds the data lock")
    }
}
