//! Model-checked thread spawn/join. Spawned threads are real OS
//! threads serialized by the scheduler; `spawn` and `join` are decision
//! points, and joining a thread that can never finish is reported as a
//! deadlock.

use std::sync::{Arc, Mutex};

use crate::rt;

pub struct JoinHandle<T> {
    pub(crate) tid: usize,
    pub(crate) result: Arc<Mutex<Option<std::thread::Result<T>>>>,
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        let sched = rt::current_sched();
        let me = rt::current_tid();
        sched.join_thread(me, self.tid);
        self.result
            .lock()
            .unwrap()
            .take()
            .expect("joined thread finished without storing a result")
    }
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    rt::spawn_thread(f)
}

pub fn yield_now() {
    rt::yield_point();
}
