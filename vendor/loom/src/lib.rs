//! A vendored, offline, loom-API-compatible **bounded model checker**.
//!
//! The build container has no network and no crates.io index, so the
//! real [`loom`](https://docs.rs/loom) crate cannot be added as a
//! dependency. This crate implements the subset of loom's API that the
//! `srigl` concurrency models use — [`model`], [`thread`], [`sync`],
//! [`cell`] — on top of a CHESS-style scheduler (`rt`):
//!
//! * every loom-managed thread is a real OS thread, but exactly one
//!   runs at a time;
//! * every sync operation is a decision point; the decision sequence is
//!   explored depth-first across repeated executions;
//! * context switches away from a runnable thread ("preemptions") are
//!   bounded (`LOOM_MAX_PREEMPTIONS`, default 2) — exploration is
//!   exhaustive *within that bound*, the standard CHESS trade-off;
//! * a state where no thread can run is reported as a deadlock with a
//!   per-thread blocked-reason dump, which is how lost wakeups and
//!   lost notifications are caught.
//!
//! **Honest limitations versus crates.io loom** (documented in the
//! repo's `docs/ANALYSIS.md`): memory orderings are collapsed to
//! sequential consistency (no Relaxed/Acquire/Release reordering), and
//! `cell::UnsafeCell` does not track concurrent-access violations
//! (serialized execution makes closure overlap impossible). The shim in
//! `rust/src/util/sync.rs` keeps the ported code source-compatible with
//! the real loom, so this crate can be swapped for it in an online
//! environment without touching the models.

mod rt;

pub mod cell;
pub mod sync;
pub mod thread;

pub use rt::model;
