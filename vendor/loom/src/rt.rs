//! The bounded scheduler behind [`crate::model`].
//!
//! Execution model: every loom-managed thread is a real OS thread, but
//! **exactly one runs at a time** — all others park on one condvar until
//! the scheduler hands them the baton. Every synchronization operation
//! (lock attempt, condvar block, atomic access, cell access, spawn,
//! yield) is a *decision point*: the scheduler picks which runnable
//! thread continues. The decision sequence of one execution is recorded
//! as a path; [`advance`] backtracks depth-first over untried
//! alternatives, so repeated executions enumerate every schedule —
//! subject to a CHESS-style *preemption bound* (switching away from a
//! thread that could have continued costs one preemption; forced
//! switches, when the current thread blocked or finished, are free).
//!
//! Within the preemption bound the exploration is exhaustive at
//! sync-operation granularity under sequentially-consistent memory;
//! see `docs/ANALYSIS.md` in the parent repo for exactly what that does
//! and does not cover compared to crates.io loom.

use std::any::Any;
use std::cell::RefCell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar as OsCondvar, Mutex as OsMutex};

/// Sentinel panic payload used to unwind parked threads when an
/// execution aborts (assertion failure or deadlock elsewhere).
pub(crate) struct AbortToken;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Run {
    Ready,
    Blocked(&'static str),
    Finished,
}

struct ThreadSlot {
    run: Run,
    /// A wakeup that arrived before the target actually parked
    /// (unblock/park races are resolved with a permit, like a parker).
    permit: bool,
    /// Threads blocked in `join` on this one.
    joiners: Vec<usize>,
}

/// One scheduling decision: which thread was chosen, out of which
/// runnable set, while which thread held the baton. Only decision
/// points with ≥ 2 runnable threads are recorded — single-candidate
/// handoffs are forced and carry no exploration value.
#[derive(Clone)]
pub(crate) struct Choice {
    pub chosen: usize,
    pub enabled: Vec<usize>,
    pub prev: usize,
    pub prev_enabled: bool,
}

struct State {
    threads: Vec<ThreadSlot>,
    active: usize,
    replay: Vec<Choice>,
    path: Vec<Choice>,
    depth: usize,
    live: usize,
    abort: bool,
    failure: Option<Box<dyn Any + Send>>,
}

pub(crate) struct Scheduler {
    st: OsMutex<State>,
    cv: OsCondvar,
    done_cv: OsCondvar,
}

thread_local! {
    static CTX: RefCell<Option<(Arc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

pub(crate) fn set_ctx(sched: Arc<Scheduler>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((sched, tid)));
}

fn ctx() -> (Arc<Scheduler>, usize) {
    CTX.with(|c| {
        c.borrow()
            .clone()
            .expect("loom primitives may only be used inside loom::model")
    })
}

/// Decision point: the current thread stays runnable; the scheduler may
/// keep it running or preempt it.
pub(crate) fn yield_point() {
    let (s, me) = ctx();
    s.yield_point(me);
}

/// Park the current thread until another thread calls [`unblock`] on it.
pub(crate) fn block(why: &'static str) {
    let (s, me) = ctx();
    s.block(me, why);
}

/// Make `tid` runnable again (or hand it a permit if it has not parked
/// yet). Does not transfer control; `tid` becomes a candidate at the
/// next decision point.
pub(crate) fn unblock(tid: usize) {
    let (s, _) = ctx();
    s.unblock(tid);
}

pub(crate) fn current_tid() -> usize {
    ctx().1
}

pub(crate) fn current_sched() -> Arc<Scheduler> {
    ctx().0
}

impl Scheduler {
    fn new(replay: Vec<Choice>) -> Scheduler {
        Scheduler {
            st: OsMutex::new(State {
                threads: Vec::new(),
                active: 0,
                replay,
                path: Vec::new(),
                depth: 0,
                live: 0,
                abort: false,
                failure: None,
            }),
            cv: OsCondvar::new(),
            done_cv: OsCondvar::new(),
        }
    }

    /// Register a new thread; returns its id. The baton is not moved.
    pub(crate) fn register(&self) -> usize {
        let mut s = self.st.lock().unwrap();
        s.threads.push(ThreadSlot { run: Run::Ready, permit: false, joiners: Vec::new() });
        s.live += 1;
        s.threads.len() - 1
    }

    /// Pick the next thread to run. `prev` is the thread that held the
    /// baton (it may itself be runnable, blocked, or finished).
    fn schedule(&self, s: &mut State, prev: usize) {
        if s.abort {
            self.cv.notify_all();
            return;
        }
        let enabled: Vec<usize> = s
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.run == Run::Ready)
            .map(|(i, _)| i)
            .collect();
        if enabled.is_empty() {
            if s.live > 0 {
                let report: Vec<String> = s
                    .threads
                    .iter()
                    .enumerate()
                    .map(|(i, t)| format!("thread {i}: {:?}", t.run))
                    .collect();
                s.failure.get_or_insert_with(|| {
                    Box::new(format!("loom: deadlock — no runnable thread ({})", report.join(", ")))
                });
                s.abort = true;
                self.cv.notify_all();
                self.done_cv.notify_all();
            }
            return;
        }
        let prev_enabled = enabled.contains(&prev);
        let chosen = if enabled.len() == 1 {
            // Forced handoff: not a decision, not recorded.
            enabled[0]
        } else {
            let d = s.depth;
            let chosen = if d < s.replay.len() {
                let c = s.replay[d].chosen;
                if !enabled.contains(&c) {
                    s.failure.get_or_insert_with(|| {
                        Box::new(
                            "loom: schedule replay diverged — the model is nondeterministic \
                             (avoid wall-clock, RNG, or iteration-order dependence)"
                                .to_string(),
                        )
                    });
                    s.abort = true;
                    self.cv.notify_all();
                    self.done_cv.notify_all();
                    return;
                }
                c
            } else if prev_enabled {
                prev
            } else {
                enabled[0]
            };
            s.path.push(Choice { chosen, enabled, prev, prev_enabled });
            s.depth += 1;
            chosen
        };
        s.active = chosen;
        self.cv.notify_all();
    }

    fn yield_point(&self, me: usize) {
        let mut s = self.st.lock().unwrap();
        if !s.abort {
            self.schedule(&mut s, me);
        }
        while !s.abort && s.active != me {
            s = self.cv.wait(s).unwrap();
        }
        if s.abort {
            drop(s);
            panic::panic_any(AbortToken);
        }
    }

    fn block(&self, me: usize, why: &'static str) {
        let mut s = self.st.lock().unwrap();
        if !s.abort {
            if s.threads[me].permit {
                s.threads[me].permit = false; // wakeup already arrived: stay runnable
            } else {
                s.threads[me].run = Run::Blocked(why);
            }
            self.schedule(&mut s, me);
        }
        while !s.abort && s.active != me {
            s = self.cv.wait(s).unwrap();
        }
        if s.abort {
            drop(s);
            panic::panic_any(AbortToken);
        }
    }

    fn unblock(&self, tid: usize) {
        let mut s = self.st.lock().unwrap();
        match s.threads[tid].run {
            Run::Blocked(_) => s.threads[tid].run = Run::Ready,
            Run::Ready => s.threads[tid].permit = true,
            Run::Finished => {}
        }
    }

    fn unblock_locked(s: &mut State, tid: usize) {
        match s.threads[tid].run {
            Run::Blocked(_) => s.threads[tid].run = Run::Ready,
            Run::Ready => s.threads[tid].permit = true,
            Run::Finished => {}
        }
    }

    /// Called by a thread wrapper after its closure returned normally.
    pub(crate) fn finish(&self, me: usize) {
        let mut s = self.st.lock().unwrap();
        s.threads[me].run = Run::Finished;
        s.live -= 1;
        let joiners = std::mem::take(&mut s.threads[me].joiners);
        for j in joiners {
            Self::unblock_locked(&mut s, j);
        }
        if s.live == 0 {
            self.done_cv.notify_all();
        } else {
            self.schedule(&mut s, me);
        }
    }

    /// Called by a thread wrapper after its closure panicked. The first
    /// real failure is kept; everything else is woken up to drain.
    pub(crate) fn fail(&self, me: usize, payload: Box<dyn Any + Send>) {
        let mut s = self.st.lock().unwrap();
        if !payload.is::<AbortToken>() {
            s.failure.get_or_insert(payload);
        }
        s.abort = true;
        s.threads[me].run = Run::Finished;
        s.live -= 1;
        self.cv.notify_all();
        self.done_cv.notify_all();
    }

    /// Block the current thread until `target` finishes.
    pub(crate) fn join_thread(&self, me: usize, target: usize) {
        loop {
            {
                let mut s = self.st.lock().unwrap();
                if s.abort {
                    drop(s);
                    panic::panic_any(AbortToken);
                }
                if s.threads[target].run == Run::Finished {
                    return;
                }
                s.threads[target].joiners.push(me);
            }
            block("join");
        }
    }

    fn wait_all_done(&self) {
        let mut s = self.st.lock().unwrap();
        while s.live > 0 {
            s = self.done_cv.wait(s).unwrap();
        }
    }

    fn take_results(&self) -> (Vec<Choice>, Option<Box<dyn Any + Send>>) {
        let mut s = self.st.lock().unwrap();
        (std::mem::take(&mut s.path), s.failure.take())
    }
}

/// Total preemptions along `path` plus the one implied by appending
/// `cand` to a decision with context `(prev, prev_enabled)`.
fn preemptions_with(path: &[Choice], prev: usize, prev_enabled: bool, cand: usize) -> usize {
    let base: usize =
        path.iter().filter(|c| c.prev_enabled && c.chosen != c.prev).count();
    base + usize::from(prev_enabled && cand != prev)
}

/// Depth-first backtracking: mutate `path` into the next unexplored
/// schedule prefix, or return false when the (preemption-bounded) space
/// is exhausted.
fn advance(path: &mut Vec<Choice>, max_preemptions: usize) -> bool {
    while let Some(last) = path.pop() {
        let pos = last
            .enabled
            .iter()
            .position(|&t| t == last.chosen)
            .expect("chosen thread must be in its own enabled set");
        for &cand in &last.enabled[pos + 1..] {
            if preemptions_with(path, last.prev, last.prev_enabled, cand) <= max_preemptions {
                path.push(Choice { chosen: cand, ..last.clone() });
                return true;
            }
        }
    }
    false
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run `f` under every schedule reachable within the preemption bound
/// (`LOOM_MAX_PREEMPTIONS`, default 2). Panics on the first failing
/// schedule, on deadlock, or if the space exceeds
/// `LOOM_MAX_ITERATIONS` (default 500_000 — a model that large should
/// be shrunk, not silently truncated).
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let max_preemptions = env_usize("LOOM_MAX_PREEMPTIONS", 2);
    let max_iterations = env_usize("LOOM_MAX_ITERATIONS", 500_000);
    let f = Arc::new(f);
    let mut prefix: Vec<Choice> = Vec::new();
    let mut iterations: usize = 0;
    loop {
        iterations += 1;
        if iterations > max_iterations {
            panic!(
                "loom: exceeded LOOM_MAX_ITERATIONS ({max_iterations}) without exhausting \
                 the schedule space — shrink the model or raise the limit"
            );
        }
        let sched = Arc::new(Scheduler::new(std::mem::take(&mut prefix)));
        let tid0 = sched.register();
        debug_assert_eq!(tid0, 0);
        let (s2, f2) = (Arc::clone(&sched), Arc::clone(&f));
        let main = std::thread::spawn(move || {
            set_ctx(Arc::clone(&s2), 0);
            match panic::catch_unwind(AssertUnwindSafe(|| f2())) {
                Ok(()) => s2.finish(0),
                Err(p) => s2.fail(0, p),
            }
        });
        sched.wait_all_done();
        let _ = main.join();
        let (path, failure) = sched.take_results();
        if let Some(payload) = failure {
            eprintln!("loom: failing schedule found after {iterations} execution(s)");
            panic::resume_unwind(payload);
        }
        prefix = path;
        if !advance(&mut prefix, max_preemptions) {
            break;
        }
    }
}

/// Spawn a loom-managed thread inside a model.
pub(crate) fn spawn_thread<F, T>(f: F) -> crate::thread::JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let sched = current_sched();
    let tid = sched.register();
    let result: Arc<OsMutex<Option<std::thread::Result<T>>>> = Arc::new(OsMutex::new(None));
    let (s2, r2) = (Arc::clone(&sched), Arc::clone(&result));
    std::thread::spawn(move || {
        set_ctx(Arc::clone(&s2), tid);
        // Wait for the baton before running any user code.
        {
            let mut st = s2.st.lock().unwrap();
            while !st.abort && st.active != tid {
                st = s2.cv.wait(st).unwrap();
            }
            if st.abort {
                drop(st);
                s2.fail(tid, Box::new(AbortToken));
                return;
            }
        }
        match panic::catch_unwind(AssertUnwindSafe(f)) {
            Ok(v) => {
                *r2.lock().unwrap() = Some(Ok(v));
                s2.finish(tid);
            }
            Err(p) => {
                if p.is::<AbortToken>() {
                    s2.fail(tid, Box::new(AbortToken));
                } else {
                    *r2.lock().unwrap() = Some(Err(Box::new("thread panicked")));
                    s2.fail(tid, p);
                }
            }
        }
    });
    // Let the scheduler consider running the child right away.
    yield_point();
    crate::thread::JoinHandle { tid, result }
}
