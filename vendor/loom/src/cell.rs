//! `loom::cell::UnsafeCell` — closure-based access (`with`/`with_mut`)
//! so every access is a scheduler decision point. Unlike crates.io
//! loom, the vendored checker does not track concurrent-access
//! violations inside the closures (execution is fully serialized, so
//! closures can never overlap); protocol races around the cell are
//! still explored via the decision points.

use crate::rt;

#[derive(Debug)]
pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

impl<T> UnsafeCell<T> {
    pub fn new(t: T) -> UnsafeCell<T> {
        UnsafeCell(std::cell::UnsafeCell::new(t))
    }

    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        rt::yield_point();
        f(self.0.get())
    }

    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        rt::yield_point();
        f(self.0.get())
    }

    pub fn into_inner(self) -> T {
        self.0.into_inner()
    }
}
