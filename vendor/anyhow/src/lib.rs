//! In-tree minimal reimplementation of the `anyhow` API surface this
//! workspace uses (offline environment — the real crate is unavailable).
//!
//! Provides [`Error`] (a message plus an optional boxed cause chain),
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. `{:#}` formatting renders
//! the full `outer: inner: ...` chain like the real crate.

use std::fmt;

/// An error: a display message plus an optional cause it wraps.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message (without the cause chain).
    pub fn to_string_outer(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(mut cur) = self.source.as_deref() {
            f.write_str("\n\nCaused by:")?;
            loop {
                write!(f, "\n    {}", cur.msg)?;
                match cur.source.as_deref() {
                    Some(next) => cur = next,
                    None => break,
                }
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, so this
// blanket conversion does not overlap the core identity `From` impl —
// the same trick the real anyhow uses.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(3).with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<f64> {
            let v: f64 = "1.5".parse()?;
            Ok(v)
        }
        assert_eq!(f().unwrap(), 1.5);
    }
}
