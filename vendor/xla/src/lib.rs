//! In-tree stub of the `xla` (xla-rs) API surface the L3 runtime uses.
//!
//! The environment has no libxla/PJRT shared library, so the client side
//! ([`PjRtClient`], [`HloModuleProto`], executables) compiles but reports
//! "backend unavailable" at run time; callers that gate on artifact
//! presence (all tests, `srigl check`) degrade gracefully. The host-side
//! [`Literal`] type is fully functional — shape + typed data marshalling
//! is real so the tensor <-> literal round-trip paths stay testable.

use std::borrow::Borrow;
use std::fmt;

#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn backend() -> Error {
        Error::new("XLA PJRT backend unavailable: built against the in-tree xla stub (no libxla)")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// Literals (fully functional host-side)
// ---------------------------------------------------------------------------

/// Typed element storage for an array literal.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn make_data(s: &[Self]) -> Data;
    #[doc(hidden)]
    fn extract(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn make_data(s: &[Self]) -> Data {
        Data::F32(s.to_vec())
    }

    fn extract(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn make_data(s: &[Self]) -> Data {
        Data::I32(s.to_vec())
    }

    fn extract(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Repr {
    Array { dims: Vec<i64>, data: Data },
    Tuple(Vec<Literal>),
}

/// A host literal: an n-d array of f32/i32, or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    repr: Repr,
}

impl Literal {
    /// A rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { repr: Repr::Array { dims: vec![], data: Data::F32(vec![v]) } }
    }

    /// A rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { repr: Repr::Array { dims: vec![data.len() as i64], data: T::make_data(data) } }
    }

    /// A tuple literal (what our AOT programs return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { repr: Repr::Tuple(parts) }
    }

    /// Reinterpret with new dimensions; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.repr {
            Repr::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want < 0 || want as usize != data.len() {
                    return Err(Error::new(format!(
                        "reshape to {dims:?} ({want} elems) from {} elems",
                        data.len()
                    )));
                }
                Ok(Literal { repr: Repr::Array { dims: dims.to_vec(), data: data.clone() } })
            }
            Repr::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    pub fn dims(&self) -> Result<Vec<i64>> {
        match &self.repr {
            Repr::Array { dims, .. } => Ok(dims.clone()),
            Repr::Tuple(_) => Err(Error::new("tuple literal has no dims")),
        }
    }

    /// Copy the elements out as `Vec<T>`; errors on element-type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::Array { data, .. } => {
                T::extract(data).ok_or_else(|| Error::new("literal element type mismatch"))
            }
            Repr::Tuple(_) => Err(Error::new("cannot to_vec a tuple literal")),
        }
    }

    /// Decompose a tuple literal; a non-tuple yields itself as a 1-tuple.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(parts) => Ok(parts),
            repr => Ok(vec![Literal { repr }]),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT client surface (stubbed: compiles, errors at run time)
// ---------------------------------------------------------------------------

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::backend())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::backend())
    }
}

pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::backend())
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::backend())
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::backend())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        assert_eq!(l.dims().unwrap(), vec![2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_and_tuple() {
        let s = Literal::scalar(7.5);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1i32, 2])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![1, 2]);
        // non-tuple decomposes to itself
        assert_eq!(s.clone().to_tuple().unwrap(), vec![s]);
    }

    #[test]
    fn reshape_validates_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3, 1]).is_err());
    }

    #[test]
    fn backend_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
