//! cargo-bench harness for paper Table 5 / Fig. 13: FLOPs accounting plus
//! *achieved* GFLOP/s of each native representation on the Fig. 4 layer —
//! the roofline context for the §Perf log in EXPERIMENTS.md.

use srigl::bench::{bench, black_box};
use srigl::exp::timings::{ablated_frac_for, VIT_FF_D, VIT_FF_N};
use srigl::flops::{cnn_proxy_flops, paper_table5};
use srigl::inference::{LayerBundle, LinearKernel};
use srigl::sparsity::distribution::{layer_densities, Distribution, LayerShape};
use srigl::util::rng::Rng;
use std::time::Duration;

fn main() {
    // --- analytic table 5 ---
    let shapes = vec![
        LayerShape { name: "conv0".into(), dims: vec![16, 3, 3, 3] },
        LayerShape { name: "conv1".into(), dims: vec![32, 16, 3, 3] },
        LayerShape { name: "conv2".into(), dims: vec![64, 32, 3, 3] },
        LayerShape { name: "fc".into(), dims: vec![10, 64] },
    ];
    println!("Table 5 — FLOPs fractions (cnn_proxy ERK vs paper ResNet-50)");
    println!("{:>9} {:>12} {:>12} {:>14} {:>14}", "sparsity", "train/dense", "infer/dense", "paper train", "paper infer");
    for (s, p_train, p_inf) in paper_table5() {
        let densities = if s == 0.0 { vec![1.0; 4] } else { layer_densities(Distribution::Erk, &shapes, s) };
        let m = cnn_proxy_flops(&[16, 32, 64], 16, 10, &densities);
        println!(
            "{:>8.0}% {:>12.3} {:>12.3} {:>14.3} {:>14.3}",
            s * 100.0,
            m.train_fraction_of_dense(20),
            m.inference() / m.inference_dense(),
            p_train / 3.15,
            p_inf / 8.20
        );
    }

    // --- achieved GFLOP/s per representation (batch 1 and 64) ---
    let sparsity = 0.9;
    let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sparsity, ablated_frac_for(sparsity), 42);
    let mut rng = Rng::new(7);
    println!("\nAchieved GFLOP/s on the Fig. 4 layer @ 90% (useful FLOPs = 2*nnz*batch):");
    for &batch in &[1usize, 64] {
        let x: Vec<f32> = (0..batch * VIT_FF_D).map(|_| rng.normal_f32()).collect();
        for k in bundle.kernels() {
            let useful = match k.name() {
                "dense" => 2.0 * (VIT_FF_N * VIT_FF_D) as f64,
                "csr" => 2.0 * bundle.csr.csr.nnz() as f64,
                "structured" => 2.0 * (bundle.structured.n_active * VIT_FF_D) as f64,
                _ => 2.0 * bundle.condensed.c.values.len() as f64,
            } * batch as f64;
            let mut out = vec![0f32; batch * k.out_width()];
            let m = bench(k.name(), 5, Duration::from_millis(30), || {
                k.forward(black_box(&x), batch, &mut out, 1);
                black_box(&out);
            });
            println!(
                "  batch {batch:>3} {:<11} {:>8.2} GFLOP/s (median {:.1} us)",
                k.name(),
                useful / m.median_s() / 1e9,
                m.median_us()
            );
        }
    }
}
