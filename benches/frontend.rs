//! Socket front-end bench: loopback clients drive the TCP serving stack
//! end-to-end (frame encode -> kernel forward -> frame decode), sweeping
//! fixed vs adaptive batching and result-cache hit ratios {0, 0.5, 0.9}.
//! Client-side latency includes the wire round trip, so numbers here sit
//! above the in-process `model_serve` bench by the loopback overhead.
//!
//! The final line is a machine-readable JSON summary (`{"bench":...}`) so
//! CI and future PRs can track the perf trajectory.

use std::sync::Arc;

use srigl::inference::server::{Batching, LatencyStats, WorkerStats};
use srigl::inference::{frontend, Activation, EngineBuilder, LayerSpec, Repr, SparseModel};
use srigl::net::Client;
use srigl::util::json::{arr, num, obj, s, Json};
use srigl::util::rng::Rng;

const N_REQUESTS: usize = 600;
const CLIENTS: usize = 2;

fn model() -> Arc<SparseModel> {
    let spec = |n, act| LayerSpec {
        n,
        repr: Repr::Condensed,
        sparsity: 0.9,
        ablated_frac: 0.35,
        activation: act,
    };
    Arc::new(
        SparseModel::synth(
            1024,
            &[
                spec(768, Activation::Relu),
                spec(768, Activation::Relu),
                spec(256, Activation::Identity),
            ],
            42,
        )
        .expect("valid stack"),
    )
}

/// Drive one configuration with `CLIENTS` loopback client threads, each
/// drawing inputs from a shared pool sized so roughly `hit_ratio` of
/// requests repeat an already-served payload.
fn run(model: &Arc<SparseModel>, batching: Batching, hit_ratio: f64) -> (LatencyStats, String) {
    let handle = frontend::spawn(
        Arc::clone(model),
        "127.0.0.1:0",
        &EngineBuilder::new()
            .workers(2)
            .batching(batching)
            .queue_capacity(1024)
            .cache_capacity(2048)
            .retry_after_ms(1),
    )
    .expect("bind loopback");
    let addr = handle.addr();
    let d = model.in_width();

    // pool of unique payloads: first use of each is a miss, reuse hits —
    // total hits ~= N_REQUESTS - pool size
    let pool_size = ((N_REQUESTS as f64 * (1.0 - hit_ratio)).round() as usize).max(1);
    let mut rng = Rng::new(7);
    let pool: Arc<Vec<Vec<f32>>> = Arc::new(
        (0..pool_size)
            .map(|_| (0..d).map(|_| rng.normal_f32()).collect())
            .collect(),
    );

    let per_client = N_REQUESTS / CLIENTS;
    let t_start = std::time::Instant::now();
    let client_stats: Vec<WorkerStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    let mut ws = WorkerStats::default();
                    // Cycle the pool (no replacement-sampling): each
                    // payload's first use is the miss, every revisit a
                    // hit, so total hits ~= N_REQUESTS - pool size and the
                    // labeled hit ratio is the actual one. Clients start
                    // half a pool apart so they never race on the same
                    // not-yet-cached payload.
                    let offset = c * pool.len() / CLIENTS;
                    for i in 0..per_client {
                        let x = &pool[(offset + i) % pool.len()];
                        let t0 = std::time::Instant::now();
                        client.infer_retrying(1, x, 100).expect("infer");
                        ws.latencies_us.push(t0.elapsed().as_secs_f64() * 1e6);
                        ws.served += 1;
                        ws.batches += 1;
                    }
                    ws
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked")).collect()
    });

    let wall_s = t_start.elapsed().as_secs_f64();
    let server = handle.stop();
    let lat = LatencyStats::from_workers(&client_stats, wall_s.max(1e-9));
    let server_line = format!(
        "hits={:<4} mean_batch={:<4.1} max_fwd={}",
        server.cache_hits, server.latency.mean_batch, server.max_forward_rows
    );
    (lat, server_line)
}

fn main() {
    let model = model();
    println!("frontend — loopback TCP serving, {}", model.describe());
    println!(
        "{N_REQUESTS} requests over {CLIENTS} sync clients, 2 workers, cache 2048 entries\n"
    );
    println!(
        "{:<10} {:>9} {:>10} {:>10} {:>10}   server",
        "batching", "hit-ratio", "p50 (us)", "p99 (us)", "req/s"
    );
    let mut rows: Vec<Json> = Vec::new();
    for batching in [Batching::Fixed(8), Batching::Adaptive { cap: 8 }] {
        for hit_ratio in [0.0f64, 0.5, 0.9] {
            let (lat, server) = run(&model, batching, hit_ratio);
            let name = match batching {
                Batching::Fixed(n) => format!("fixed({n})"),
                Batching::Adaptive { cap } => format!("adapt({cap})"),
            };
            println!(
                "{name:<10} {hit_ratio:>9.1} {:>10.1} {:>10.1} {:>10.0}   {server}",
                lat.p50_us, lat.p99_us, lat.throughput_rps
            );
            rows.push(obj(vec![
                ("batching", s(&name)),
                ("hit_ratio", num(hit_ratio)),
                ("p50_us", num(lat.p50_us)),
                ("p99_us", num(lat.p99_us)),
                ("rps", num(lat.throughput_rps)),
            ]));
        }
    }
    println!("\n(sync clients: one request in flight each, so req/s is latency-bound;");
    println!(" higher hit ratios should cut p50 toward the wire round-trip floor)");
    let summary = obj(vec![
        ("bench", s("frontend")),
        ("n_requests", num(N_REQUESTS as f64)),
        ("clients", num(CLIENTS as f64)),
        ("rows", arr(rows)),
    ]);
    println!("{}", summary.to_string());
    srigl::arena::persist_bench_summary("frontend", &summary);
}
