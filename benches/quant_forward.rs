//! f32-vs-int8 duel on the Fig. 4 ViT-FF layer geometry (768x3072 @ 90%
//! sparse, 10% neurons ablated): the f32 condensed pair against the
//! quantized condensed pair, plus a scalar-forced int8 lane so the JSON
//! line shows what the `vpmaddwd` integer MACs buy over the integer
//! oracle on each machine.
//!
//! Before any timing, every quantized output is checked against the f32
//! condensed oracle under the documented per-row error budget
//! (`QuantizedCondensed::row_error_bound`, docs/KERNELS.md) — a bench
//! that got faster by drifting out of budget must fail loudly, not
//! persist a flattering number. The final line is a machine-readable
//! `{"bench":...}` summary persisted via `arena::persist_bench_summary`
//! so CI tracks the int8 speedup and the storage ratio across machines.

use srigl::bench::{bench, black_box, Measurement};
use srigl::inference::{LayerBundle, LinearKernel, QuantizedLayer};
use srigl::kernels::{self, KernelKind, Microkernel};
use srigl::util::json::{arr, num, obj, s, Json};
use std::time::Duration;

fn main() {
    let (n, d, sparsity, ablated) = (768usize, 3072usize, 0.9, 0.1);
    let bundle = LayerBundle::synth(n, d, sparsity, ablated, 42);
    let mut quant_scalar =
        QuantizedLayer::new(&bundle.w, &bundle.mask, &bundle.bias).expect("u16-indexable width");
    quant_scalar.mk = Microkernel::of(KernelKind::Scalar);

    let kernels_under_test: Vec<(&str, &dyn LinearKernel)> = vec![
        ("condensed", &bundle.condensed),
        ("condensed-tiled", &bundle.condensed_tiled),
        ("quantized[scalar]", &quant_scalar),
        ("quantized", &bundle.quantized),
        ("quantized-tiled", &bundle.quantized_tiled),
    ];

    let q = &bundle.quantized.q;
    let na = q.n_active();
    println!(
        "quant_forward — {n}x{d} @ {:.0}% sparsity, {:.0}% ablated, dispatch {}",
        sparsity * 100.0,
        ablated * 100.0,
        kernels::describe_selection()
    );
    println!(
        "f32 condensed {} KiB -> int8 quantized {} KiB ({:.2}x smaller)",
        bundle.condensed.storage_bytes() / 1024,
        bundle.quantized.storage_bytes() / 1024,
        bundle.condensed.storage_bytes() as f64 / bundle.quantized.storage_bytes() as f64
    );
    println!(
        "{:>18} {:>6} {:>8} {:>12} {:>10} {:>8}",
        "kernel", "batch", "threads", "median (us)", "GFLOP/s", "vs f32"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut rng = srigl::util::rng::Rng::new(7);
    // (batch=256, threads=1) medians for the headline comparison
    let mut f32_tiled_256_us = 0.0f64;
    let mut int8_tiled_256_us = 0.0f64;
    for &batch in &[1usize, 8, 256] {
        let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();

        // Correctness gate before timing: every quantized lane must stay
        // within the documented per-row budget of the f32 oracle.
        let mut want = vec![0f32; batch * na];
        bundle.condensed.forward(&x, batch, &mut want, 1);
        for (name, kernel) in &kernels_under_test {
            if !name.starts_with("quantized") {
                continue;
            }
            let mut got = vec![0f32; batch * na];
            kernel.forward(&x, batch, &mut got, 1);
            for b in 0..batch {
                let xmax = x[b * d..(b + 1) * d].iter().fold(0f32, |m, &v| m.max(v.abs()));
                for r in 0..na {
                    let budget = q.row_error_bound(r, xmax) * 1.01 + 1e-5;
                    let err = (got[b * na + r] - want[b * na + r]).abs();
                    assert!(
                        err <= budget,
                        "{name} batch {batch} row {r}: error {err} exceeds budget {budget}"
                    );
                }
            }
        }

        for &threads in &[1usize, 4] {
            // per-(batch, threads) f32 tiled baseline for the speedup column
            let mut f32_us = 0.0f64;
            for (name, kernel) in &kernels_under_test {
                let mut out = vec![0f32; batch * kernel.out_width()];
                let m: Measurement = bench(name, 5, Duration::from_millis(40), || {
                    kernel.forward(black_box(&x), batch, &mut out, threads);
                    black_box(&out);
                });
                let med_us = m.median_us();
                // 2 MACs per stored weight per example — the MAC count is
                // representation-independent, so int8 GFLOP/s are directly
                // comparable to f32 (they are "effective" FLOPs)
                let stored: usize = kernel.row_weights(n).iter().sum();
                let gflops = 2.0 * stored as f64 * batch as f64 / m.median_s().max(1e-12) / 1e9;
                if *name == "condensed-tiled" {
                    f32_us = med_us;
                    if batch == 256 && threads == 1 {
                        f32_tiled_256_us = med_us;
                    }
                }
                if *name == "quantized-tiled" && batch == 256 && threads == 1 {
                    int8_tiled_256_us = med_us;
                }
                let speed = if f32_us > 0.0 && name.starts_with("quantized") {
                    format!("{:.2}x", f32_us / med_us)
                } else {
                    "-".into()
                };
                println!(
                    "{name:>18} {batch:>6} {threads:>8} {med_us:>12.1} {gflops:>10.2} {speed:>8}"
                );
                rows.push(obj(vec![
                    ("kernel", s(name)),
                    ("batch", num(batch as f64)),
                    ("threads", num(threads as f64)),
                    ("median_us", num(med_us)),
                    ("gflops", num(gflops)),
                ]));
            }
        }
    }
    if f32_tiled_256_us > 0.0 && int8_tiled_256_us > 0.0 {
        println!(
            "\nbatch-256 headline: quantized-tiled {:.2}x vs f32 condensed-tiled \
             (outputs within the documented error budget)",
            f32_tiled_256_us / int8_tiled_256_us
        );
    }
    let summary = obj(vec![
        ("bench", s("quant_forward")),
        ("kernel", s(kernels::selected().name())),
        ("tile", num(kernels::TILE as f64)),
        ("n", num(n as f64)),
        ("d", num(d as f64)),
        ("sparsity", num(sparsity)),
        ("ablated_frac", num(ablated)),
        ("f32_bytes", num(bundle.condensed.storage_bytes() as f64)),
        ("int8_bytes", num(bundle.quantized.storage_bytes() as f64)),
        (
            "int8_speedup_b256",
            num(if int8_tiled_256_us > 0.0 { f32_tiled_256_us / int8_tiled_256_us } else { 0.0 }),
        ),
        ("rows", arr(rows)),
    ]);
    println!("{}", summary.to_string());
    srigl::arena::persist_bench_summary("quant_forward", &summary);
}
