//! Worker-pool serving bench: sweep workers x representation on a 3-layer
//! sparse model (ViT-FF-shaped trunk), flooding the queue so throughput is
//! compute-bound. Reports req/s and tail latency per configuration; the
//! pool should scale with workers on multi-core hosts (on the 1-core
//! testbed the sweep exercises coordination overhead instead — same caveat
//! as benches/fig18_thread_sweep.rs).
//!
//! The final line is a machine-readable JSON summary (`{"bench":...}`) so
//! CI and future PRs can track the perf trajectory; everything above it is
//! for humans.

use std::time::Duration;

use srigl::inference::server::{serve_model, ServeConfig};
use srigl::inference::{Activation, EngineBuilder, LayerSpec, Repr, SparseModel};
use srigl::util::json::{arr, num, obj, s, Json};

fn model_for(repr: Repr, sparsity: f64) -> SparseModel {
    let spec = |n, act| LayerSpec { n, repr, sparsity, ablated_frac: 0.35, activation: act };
    SparseModel::synth(
        1024,
        &[
            spec(768, Activation::Relu),
            spec(768, Activation::Relu),
            spec(256, Activation::Identity),
        ],
        42,
    )
    .expect("valid stack")
}

fn main() {
    let sparsity = 0.9;
    let n_requests = 1024;
    let max_batch = 8;
    println!("model_serve — 3-layer 1024->768->768->256 @ {:.0}% sparsity,", sparsity * 100.0);
    println!("{n_requests} flooded requests, max_batch={max_batch}, 1 intra-op thread\n");
    println!(
        "{:>11} {:>8} {:>10} {:>10} {:>12} {:>9}",
        "repr", "workers", "p50 (us)", "p99 (us)", "req/s", "scaling"
    );
    let mut rows: Vec<Json> = Vec::new();
    for repr in Repr::ALL {
        let model = model_for(repr, sparsity);
        let mut base = 0.0f64;
        for workers in [1usize, 2, 4] {
            let stats = serve_model(
                &model,
                &EngineBuilder::new().workers(workers).fixed_batch(max_batch),
                &ServeConfig { n_requests, mean_interarrival: Duration::ZERO, seed: 7 },
            )
            .expect("replicated serving cannot fail");
            if workers == 1 {
                base = stats.throughput_rps;
            }
            println!(
                "{:>11} {:>8} {:>10.1} {:>10.1} {:>12.0} {:>8.2}x",
                repr.name(),
                workers,
                stats.p50_us,
                stats.p99_us,
                stats.throughput_rps,
                stats.throughput_rps / base.max(1e-9)
            );
            rows.push(obj(vec![
                ("repr", s(repr.name())),
                ("workers", num(workers as f64)),
                ("p50_us", num(stats.p50_us)),
                ("p99_us", num(stats.p99_us)),
                ("rps", num(stats.throughput_rps)),
            ]));
        }
    }
    println!("\n(scaling column is throughput relative to the same repr at workers=1)");
    let summary = obj(vec![
        ("bench", s("model_serve")),
        ("sparsity", num(sparsity)),
        ("n_requests", num(n_requests as f64)),
        ("max_batch", num(max_batch as f64)),
        ("rows", arr(rows)),
    ]);
    println!("{}", summary.to_string());
    srigl::arena::persist_bench_summary("model_serve", &summary);
}
