//! cargo-bench harness for the end-to-end training hot path: per-model
//! train_step latency through PJRT, the dense_grad saliency pass, and the
//! pure-rust SRigL mask update — quantifying the L3 overhead the paper's
//! architecture amortizes over ΔT steps. Skips cleanly if artifacts are
//! missing (run `make artifacts`).

use srigl::bench::{bench, fmt_time};
use srigl::dst::{LayerView, SRigL, TopologyUpdater};
use srigl::runtime::Manifest;
use srigl::sparsity::Distribution;
use srigl::tensor::Tensor;
use srigl::train::{LrSchedule, Method, Session, TrainConfig};
use srigl::util::rng::Rng;
use std::time::Duration;

fn main() {
    if !Manifest::default_dir().join("manifest.json").exists() {
        println!("skipping e2e bench: run `make artifacts` first");
        return;
    }
    let sess = Session::open().expect("session");
    println!("{:<12} {:>14} {:>14} {:>16} {:>10}", "model", "train_step", "dense_grad", "mask_update(L3)", "L3 share");
    for model in ["mlp_tiny", "mlp_proxy", "cnn_proxy", "vit_proxy", "lm_small"] {
        if sess.man.models.get(model).is_none() {
            continue;
        }
        let cfg = TrainConfig {
            model: model.into(),
            method: Method::SRigL { ablation: true, gamma_sal: 0.3 },
            sparsity: 0.9,
            distribution: Distribution::Erk,
            total_steps: 100,
            delta_t: 10,
            alpha: 0.3,
            lr: LrSchedule::Const(0.05),
            grad_accum: 1,
            seed: 0,
            eval_batches: 1,
            dense_first_layer: false,
        };
        let mut tr = sess.trainer(cfg).expect("trainer");
        // warm the executables
        tr.step(0).unwrap();

        let mut i = 1usize;
        let m_step = bench("train_step", 5, Duration::from_millis(100), || {
            tr.step(i).unwrap();
            i += 1;
        });
        let m_grad = bench("dense_grad", 5, Duration::from_millis(100), || {
            tr.dense_grads().unwrap();
        });

        // isolated L3 mask update on a copy of the largest sparse layer
        let li = (0..tr.sparse_idx.len())
            .max_by_key(|&l| tr.masks[l].t.numel())
            .unwrap_or(0);
        let pi = tr.sparse_idx[li];
        let shape = tr.entry.params[pi].shape.clone();
        let mut rng = Rng::new(1);
        let grad = Tensor::normal(&shape, 1.0, &mut rng);
        let budget = tr.budgets[li];
        let m_update = bench("mask_update", 5, Duration::from_millis(50), || {
            let mut w = tr.params[pi].clone();
            let mut v = tr.momenta[pi].clone();
            let mut mask = tr.masks[li].clone();
            let mut k = tr.ks[li];
            let mut view = LayerView {
                w: &mut w,
                v: &mut v,
                mask: &mut mask,
                grad: &grad,
                k: &mut k,
                budget,
            };
            SRigL::default().update(&mut view, 0.3, &mut rng);
        });

        // L3 share per delta_t window: (grad + update) / (delta_t*step + grad + update)
        let dt = 10.0;
        let overhead = m_grad.median_s() + m_update.median_s();
        let share = overhead / (dt * m_step.median_s() + overhead);
        println!(
            "{:<12} {:>14} {:>14} {:>16} {:>9.1}%",
            model,
            fmt_time(m_step.median_s()),
            fmt_time(m_grad.median_s()),
            fmt_time(m_update.median_s()),
            share * 100.0
        );
    }
    println!("\ntarget (DESIGN.md §8): L3 share of the ΔT window < 10%");
}
