//! Replicated-vs-sharded serving sweep: the same 3-layer trunk served by
//! (a) a replicated worker pool of S workers, each owning a full model
//! scratch, and (b) one coordinator feeding each forward to a
//! **persistent S-shard team** (`EngineBuilder::shards`). Flooded queue,
//! so throughput is compute-bound; p50/p99 use the interpolated
//! percentile.
//!
//! What to look for: replicated wins on throughput under a flood (batching
//! amortizes per-request cost across independent cores), sharded wins on
//! single-request latency for wide layers (the work of one request is
//! split S ways) and holds scratch memory constant instead of S-fold —
//! and the persistent team pays zero thread spawns per request (the old
//! scoped-spawn path cost tens of microseconds per forward).
//! On the 1-core CI testbed both mostly measure coordination overhead —
//! same caveat as benches/model_serve.rs.
//!
//! The final line is a machine-readable JSON summary (`{"bench":...}`) so
//! CI and future PRs can track the perf trajectory.

use std::time::Duration;

use srigl::inference::server::{serve_model, serve_target, LatencyStats, ServeConfig};
use srigl::inference::shard::ShardPlan;
use srigl::inference::{
    Activation, EngineBuilder, LayerSpec, PersistentShardedEngine, Repr, SparseModel,
};
use srigl::util::json::{arr, num, obj, s, Json};

fn model_for(repr: Repr, sparsity: f64) -> SparseModel {
    let spec = |n, act| LayerSpec { n, repr, sparsity, ablated_frac: 0.35, activation: act };
    SparseModel::synth(
        1024,
        &[
            spec(768, Activation::Relu),
            spec(768, Activation::Relu),
            spec(256, Activation::Identity),
        ],
        42,
    )
    .expect("valid stack")
}

fn run(model: &SparseModel, builder: &EngineBuilder, n_requests: usize) -> LatencyStats {
    serve_model(
        model,
        builder,
        &ServeConfig { n_requests, mean_interarrival: Duration::ZERO, seed: 7 },
    )
    .expect("plan within layer widths")
}

/// The sharded column always measures a REAL persistent team — including
/// S=1, where the row isolates pure team-coordination overhead (mailbox
/// post + latch) against the in-thread replicated baseline. (Routing
/// through `serve_model` would silently fall back to the replicated
/// engine at shards=1 and compare the same code path against itself.)
fn run_team(model: &SparseModel, cap: usize, shards: usize, n_requests: usize) -> LatencyStats {
    let team = PersistentShardedEngine::from_model(model, shards).expect("plan fits");
    serve_target(
        &team,
        &EngineBuilder::new().workers(1).fixed_batch(cap),
        &ServeConfig { n_requests, mean_interarrival: Duration::ZERO, seed: 7 },
    )
}

fn main() {
    let sparsity = 0.9;
    let n_requests = 1024;
    let cap = 8;
    println!("shard_serve — 3-layer 1024->768->768->256 @ {:.0}% sparsity,", sparsity * 100.0);
    println!(
        "{n_requests} flooded requests, cap={cap}, 1 intra-shard thread, persistent shard team\n"
    );
    println!(
        "{:>11} {:>3} | {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10} | {:>7}",
        "repr", "S", "repl p50", "repl p99", "repl rps", "shard p50", "shard p99", "shard rps", "ratio"
    );
    let mut rows: Vec<Json> = Vec::new();
    for repr in Repr::ALL {
        let model = model_for(repr, sparsity);
        for shards in [1usize, 2, 4] {
            let rep = run(&model, &EngineBuilder::new().workers(shards).fixed_batch(cap), n_requests);
            let sh = run_team(&model, cap, shards, n_requests);
            println!(
                "{:>11} {:>3} | {:>10.1} {:>10.1} {:>10.0} | {:>10.1} {:>10.1} {:>10.0} | {:>6.2}x",
                repr.name(),
                shards,
                rep.p50_us,
                rep.p99_us,
                rep.throughput_rps,
                sh.p50_us,
                sh.p99_us,
                sh.throughput_rps,
                sh.throughput_rps / rep.throughput_rps.max(1e-9)
            );
            rows.push(obj(vec![
                ("repr", s(repr.name())),
                ("shards", num(shards as f64)),
                ("repl_p50_us", num(rep.p50_us)),
                ("repl_rps", num(rep.throughput_rps)),
                ("shard_p50_us", num(sh.p50_us)),
                ("shard_rps", num(sh.throughput_rps)),
            ]));
        }
    }
    // how evenly the stored-weight-balanced plan splits each layer
    let model = model_for(Repr::Condensed, sparsity);
    let plan = ShardPlan::balanced(&model, 4).expect("4 shards fit every layer");
    let imb: Vec<String> =
        (0..model.depth()).map(|l| format!("{:.3}", plan.imbalance(&model, l))).collect();
    println!(
        "\n(ratio = sharded/replicated throughput; condensed 4-shard plan imbalance per layer: [{}],",
        imb.join(", ")
    );
    println!(" 1.0 = perfectly even stored weights per shard — ablated neurons cost nothing)");
    let summary = obj(vec![
        ("bench", s("shard_serve")),
        ("sparsity", num(sparsity)),
        ("n_requests", num(n_requests as f64)),
        ("cap", num(cap as f64)),
        ("rows", arr(rows)),
    ]);
    println!("{}", summary.to_string());
    srigl::arena::persist_bench_summary("shard_serve", &summary);
}
