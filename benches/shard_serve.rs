//! Replicated-vs-sharded serving sweep: the same 3-layer trunk served by
//! (a) a replicated worker pool of S workers, each owning a full model
//! scratch, and (b) one coordinator fanning each forward over an S-shard
//! tensor-parallel team (`ServeMode::Sharded`). Flooded queue, so
//! throughput is compute-bound; p50/p99 use the interpolated percentile.
//!
//! What to look for: replicated wins on throughput under a flood (batching
//! amortizes per-request cost across independent cores), sharded wins on
//! single-request latency for wide layers (the work of one request is
//! split S ways) and holds scratch memory constant instead of S-fold.
//! On the 1-core CI testbed both mostly measure coordination overhead —
//! same caveat as benches/model_serve.rs.

use std::time::Duration;

use srigl::inference::server::{serve_model, LatencyStats, ServeConfig, ServeMode};
use srigl::inference::shard::ShardPlan;
use srigl::inference::{Activation, LayerSpec, Repr, SparseModel};

fn model_for(repr: Repr, sparsity: f64) -> SparseModel {
    let spec = |n, act| LayerSpec { n, repr, sparsity, ablated_frac: 0.35, activation: act };
    SparseModel::synth(
        1024,
        &[
            spec(768, Activation::Relu),
            spec(768, Activation::Relu),
            spec(256, Activation::Identity),
        ],
        42,
    )
    .expect("valid stack")
}

fn run(model: &SparseModel, mode: ServeMode, n_requests: usize) -> LatencyStats {
    serve_model(
        model,
        &ServeConfig {
            mode,
            n_requests,
            mean_interarrival: Duration::ZERO,
            threads: 1,
            seed: 7,
        },
    )
}

fn main() {
    let sparsity = 0.9;
    let n_requests = 1024;
    let cap = 8;
    println!("shard_serve — 3-layer 1024->768->768->256 @ {:.0}% sparsity,", sparsity * 100.0);
    println!("{n_requests} flooded requests, cap={cap}, 1 intra-op/intra-shard thread\n");
    println!(
        "{:>11} {:>3} | {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10} | {:>7}",
        "repr", "S", "repl p50", "repl p99", "repl rps", "shard p50", "shard p99", "shard rps", "ratio"
    );
    for repr in Repr::ALL {
        let model = model_for(repr, sparsity);
        for shards in [1usize, 2, 4] {
            let rep = run(&model, ServeMode::Pooled { workers: shards, max_batch: cap }, n_requests);
            let sh = run(&model, ServeMode::Sharded { shards, cap }, n_requests);
            println!(
                "{:>11} {:>3} | {:>10.1} {:>10.1} {:>10.0} | {:>10.1} {:>10.1} {:>10.0} | {:>6.2}x",
                repr.name(),
                shards,
                rep.p50_us,
                rep.p99_us,
                rep.throughput_rps,
                sh.p50_us,
                sh.p99_us,
                sh.throughput_rps,
                sh.throughput_rps / rep.throughput_rps.max(1e-9)
            );
        }
    }
    // how evenly the stored-weight-balanced plan splits each layer
    let model = model_for(Repr::Condensed, sparsity);
    let plan = ShardPlan::balanced(&model, 4);
    let imb: Vec<String> =
        (0..model.depth()).map(|l| format!("{:.3}", plan.imbalance(&model, l))).collect();
    println!(
        "\n(ratio = sharded/replicated throughput; condensed 4-shard plan imbalance per layer: [{}],",
        imb.join(", ")
    );
    println!(" 1.0 = perfectly even stored weights per shard — ablated neurons cost nothing)");
}
