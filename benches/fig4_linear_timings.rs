//! cargo-bench harness for paper Fig. 4: dense vs CSR vs structured vs
//! condensed on the 768x3072 ViT FF layer, batch 1 (Fig. 4a) and 256
//! (Fig. 4b substitute). Reports median ± stddev over >= 5 runs, matching
//! the paper's protocol. (In-tree harness replaces criterion — offline.)

use srigl::bench::{bench, black_box, print_table, Measurement};
use srigl::exp::timings::{ablated_frac_for, VIT_FF_D, VIT_FF_N};
use srigl::inference::LayerBundle;
use srigl::util::rng::Rng;
use std::time::Duration;

fn main() {
    let mut rng = Rng::new(7);
    for &batch in &[1usize, 256] {
        println!("\n===== Fig. 4 — batch {batch} =====");
        for &sparsity in &[0.8, 0.9, 0.95, 0.99] {
            let bundle =
                LayerBundle::synth(VIT_FF_N, VIT_FF_D, sparsity, ablated_frac_for(sparsity), 42);
            let x: Vec<f32> = (0..batch * VIT_FF_D).map(|_| rng.normal_f32()).collect();
            let ms: Vec<Measurement> = bundle
                .kernels()
                .iter()
                .map(|k| {
                    let mut out = vec![0f32; batch * k.out_width()];
                    bench(k.name(), 5, Duration::from_millis(40), || {
                        k.forward(black_box(&x), batch, &mut out, 1);
                        black_box(&out);
                    })
                })
                .collect();
            print_table(
                &format!("sparsity {:.0}%, batch {batch}", sparsity * 100.0),
                &ms,
                Some("dense"),
            );
        }
    }
    println!("\npaper @90%/batch1: condensed 3.4x dense, 2.5x CSR; @90%/batch256: 1.7x dense, 13x CSR (GPU)");
}
