//! Kernel-forward sweep: dense vs CSR vs condensed (scalar-forced and
//! auto-dispatched) vs batch-tiled condensed, across batch {1, 8, 256}
//! and threads {1, 4}, on the Fig. 4 ViT-FF layer geometry (768x3072 @
//! 90% sparse, 10% neurons ablated).
//!
//! `condensed[scalar]` pins the pre-kernels/ state of the repo (the
//! 4-way-unrolled scalar gather-MAC), so the JSON line shows exactly what
//! the runtime-dispatched SIMD + tiled layout buy on each machine. The
//! final line is a machine-readable `{"bench":...}` summary (util::json)
//! including the selected kernel kind, so CI and future PRs can track
//! kernel selection and the perf trajectory across machines.

use srigl::bench::{bench, black_box, Measurement};
use srigl::inference::{CondensedLayer, LayerBundle, LinearKernel};
use srigl::kernels::{self, KernelKind, Microkernel};
use srigl::util::json::{arr, num, obj, s, Json};
use std::time::Duration;

fn main() {
    let (n, d, sparsity, ablated) = (768usize, 3072usize, 0.9, 0.1);
    let bundle = LayerBundle::synth(n, d, sparsity, ablated, 42);
    let mut condensed_scalar =
        CondensedLayer::new(&bundle.w, &bundle.mask, &bundle.bias).expect("constant fan-in");
    condensed_scalar.mk = Microkernel::of(KernelKind::Scalar);

    let kernels_under_test: Vec<(&str, &dyn LinearKernel)> = vec![
        ("dense", &bundle.dense),
        ("csr", &bundle.csr_unstructured),
        ("condensed[scalar]", &condensed_scalar),
        ("condensed", &bundle.condensed),
        ("condensed-tiled", &bundle.condensed_tiled),
    ];

    println!(
        "kernel_forward — {n}x{d} @ {:.0}% sparsity, {:.0}% ablated, dispatch {}",
        sparsity * 100.0,
        ablated * 100.0,
        kernels::describe_selection()
    );
    println!(
        "{:>18} {:>6} {:>8} {:>12} {:>10} {:>9}",
        "kernel", "batch", "threads", "median (us)", "GFLOP/s", "vs scalar"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut rng = srigl::util::rng::Rng::new(7);
    // (batch=256, threads=1) medians for the acceptance check below
    let mut scalar_256_us = 0.0f64;
    let mut tiled_256_us = 0.0f64;
    for &batch in &[1usize, 8, 256] {
        let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32()).collect();
        for &threads in &[1usize, 4] {
            // per-(batch, threads) scalar baseline for the speedup column
            let mut scalar_us = 0.0f64;
            for (name, kernel) in &kernels_under_test {
                let mut out = vec![0f32; batch * kernel.out_width()];
                let m: Measurement = bench(name, 5, Duration::from_millis(40), || {
                    kernel.forward(black_box(&x), batch, &mut out, threads);
                    black_box(&out);
                });
                let med_us = m.median_us();
                // 2 FLOPs per stored weight per example (compact forms are
                // credited only for rows they actually compute)
                let stored: usize = kernel.row_weights(n).iter().sum();
                let gflops = 2.0 * stored as f64 * batch as f64 / m.median_s().max(1e-12) / 1e9;
                if *name == "condensed[scalar]" {
                    scalar_us = med_us;
                    if batch == 256 && threads == 1 {
                        scalar_256_us = med_us;
                    }
                }
                if *name == "condensed-tiled" && batch == 256 && threads == 1 {
                    tiled_256_us = med_us;
                }
                let speed = if scalar_us > 0.0 && *name != "condensed[scalar]" {
                    format!("{:.2}x", scalar_us / med_us)
                } else {
                    "-".into()
                };
                println!(
                    "{name:>18} {batch:>6} {threads:>8} {med_us:>12.1} {gflops:>10.2} {speed:>9}"
                );
                rows.push(obj(vec![
                    ("kernel", s(name)),
                    ("batch", num(batch as f64)),
                    ("threads", num(threads as f64)),
                    ("median_us", num(med_us)),
                    ("gflops", num(gflops)),
                ]));
            }
        }
    }
    if scalar_256_us > 0.0 && tiled_256_us > 0.0 {
        println!(
            "\nbatch-256 headline: condensed-tiled {:.2}x vs the scalar condensed kernel",
            scalar_256_us / tiled_256_us
        );
    }
    let summary = obj(vec![
        ("bench", s("kernel_forward")),
        ("kernel", s(kernels::selected().name())),
        ("tile", num(kernels::TILE as f64)),
        ("n", num(n as f64)),
        ("d", num(d as f64)),
        ("sparsity", num(sparsity)),
        ("ablated_frac", num(ablated)),
        ("rows", arr(rows)),
    ]);
    println!("{}", summary.to_string());
    srigl::arena::persist_bench_summary("kernel_forward", &summary);
}
