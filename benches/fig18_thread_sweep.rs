//! cargo-bench harness for paper Figs. 18-20: threads x batch sweep of
//! the four representations at 90% sparsity. The testbed has a single
//! physical core, so thread counts > 1 exercise the coordination path
//! (scoped-thread splitting) rather than real parallel speedup — recorded
//! as such in EXPERIMENTS.md.

use srigl::bench::{bench, black_box, fmt_time};
use srigl::exp::timings::{ablated_frac_for, VIT_FF_D, VIT_FF_N};
use srigl::inference::LayerBundle;
use srigl::util::rng::Rng;
use std::time::Duration;

fn main() {
    let sparsity = 0.9;
    let bundle = LayerBundle::synth(VIT_FF_N, VIT_FF_D, sparsity, ablated_frac_for(sparsity), 42);
    let mut rng = Rng::new(7);
    println!("Figs. 18-20 — 90% sparsity, median seconds per forward");
    println!(
        "{:>7} {:>6} {:>12} {:>12} {:>12} {:>12}",
        "threads", "batch", "dense", "csr", "structured", "condensed"
    );
    for &threads in &[1usize, 4, 8] {
        for &batch in &[1usize, 4, 16, 64] {
            let x: Vec<f32> = (0..batch * VIT_FF_D).map(|_| rng.normal_f32()).collect();
            let med: Vec<f64> = bundle
                .kernels()
                .iter()
                .map(|k| {
                    let mut out = vec![0f32; batch * k.out_width()];
                    bench(k.name(), 5, Duration::from_millis(25), || {
                        k.forward(black_box(&x), batch, &mut out, threads);
                        black_box(&out);
                    })
                    .median_s()
                })
                .collect();
            println!(
                "{:>7} {:>6} {:>12} {:>12} {:>12} {:>12}",
                threads,
                batch,
                fmt_time(med[0]),
                fmt_time(med[1]),
                fmt_time(med[2]),
                fmt_time(med[3])
            );
        }
    }
}
